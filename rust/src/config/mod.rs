//! Typed configuration system with JSON load/save (no serde offline —
//! (de)serialization goes through [`crate::util::json`]).
//!
//! [`HierarchyCfg::table1`] encodes the paper's simulated system (Table I)
//! exactly; everything an experiment varies (prefetcher kind, table sizes,
//! window, controller) hangs off [`SimConfig`].

use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// One cache level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheCfg {
    pub size_kb: u32,
    pub ways: u32,
    pub line_b: u32,
    /// Access latency in cycles.
    pub latency: u64,
}

impl CacheCfg {
    pub fn lines(&self) -> u32 {
        self.size_kb * 1024 / self.line_b
    }

    pub fn sets(&self) -> u32 {
        self.lines() / self.ways
    }
}

/// The full memory hierarchy (paper Table I).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HierarchyCfg {
    pub l1i: CacheCfg,
    pub l1d: CacheCfg,
    pub l2: CacheCfg,
    pub l3: CacheCfg,
    /// Fixed DRAM access latency (cycles) before bandwidth queueing.
    pub dram_latency: u64,
    /// DRAM bandwidth in bytes/cycle (25.6 GB/s at 2.5 GHz = 10.24 B/cyc).
    pub dram_bytes_per_cycle: f64,
    /// CPU frequency in GHz (reporting only).
    pub freq_ghz: f64,
}

impl HierarchyCfg {
    /// Paper Table I: 2.5 GHz; L1I 32 KB/8w/4cyc; L1D 48 KB/12w/5cyc (NLP);
    /// L2 512 KB/8w/15cyc; L3 2 MB/16w/35cyc; DRAM 1ch 3200 MT/s (25.6 GB/s).
    pub fn table1() -> Self {
        HierarchyCfg {
            l1i: CacheCfg { size_kb: 32, ways: 8, line_b: 64, latency: 4 },
            l1d: CacheCfg { size_kb: 48, ways: 12, line_b: 64, latency: 5 },
            l2: CacheCfg { size_kb: 512, ways: 8, line_b: 64, latency: 15 },
            l3: CacheCfg { size_kb: 2048, ways: 16, line_b: 64, latency: 35 },
            dram_latency: 90,
            dram_bytes_per_cycle: 25.6 / 2.5,
            freq_ghz: 2.5,
        }
    }
}

/// Which prefetcher drives the L1I (a next-line prefetcher remains enabled
/// for all variants, per §X-B).
#[derive(Clone, Debug, PartialEq)]
pub enum PrefetcherKind {
    /// Next-line only (the baseline every speedup is relative to).
    NextLineOnly,
    /// Entangling prefetcher with full-address destinations (EIP-K).
    Eip { entries: u32 },
    /// Compressed-entry EIP (CEIP-K) with the 36-bit entry.
    Ceip { entries: u32, window: u8, whole_window: bool },
    /// CEIP + hierarchical metadata (CHEIP): L1-attached entries plus a
    /// virtualized table of `vt_entries` (2K or 4K in the paper).
    Cheip { vt_entries: u32, window: u8, whole_window: bool },
    /// Oracle lookahead prefetcher (Fig 6 upper bound).
    Perfect,
}

impl PrefetcherKind {
    pub fn label(&self) -> String {
        match self {
            PrefetcherKind::NextLineOnly => "nl".into(),
            PrefetcherKind::Eip { entries } => format!("eip{entries}"),
            PrefetcherKind::Ceip { entries, window, whole_window } => {
                format!("ceip{entries}w{window}{}", if *whole_window { "" } else { "s" })
            }
            PrefetcherKind::Cheip { vt_entries, window, whole_window } => {
                format!("cheip{vt_entries}w{window}{}", if *whole_window { "" } else { "s" })
            }
            PrefetcherKind::Perfect => "perfect".into(),
        }
    }
}

/// Online ML controller configuration (paper §IV).
#[derive(Clone, Debug, PartialEq)]
pub struct ControllerCfg {
    /// Enable the logistic gate + bandit threshold.
    pub enabled: bool,
    /// Initial decision threshold (bandit-adjusted afterwards).
    pub threshold: f32,
    /// Cycles between training steps ("millisecond granularity": 1 ms at
    /// 2.5 GHz = 2.5 M cycles).
    pub train_interval_cycles: u64,
    /// SGD learning rate.
    pub lr: f32,
    /// Bandit exploration rate.
    pub epsilon: f64,
    /// Allow the bandit to choose window size in {4, 8, 12}.
    pub adapt_window: bool,
    /// Hard issuance budget: max prefetches per 1k cycles (0 = uncapped) —
    /// the deployment playbook's "tokens per ms" knob.
    pub issue_budget_per_kcycle: u32,
    /// Shadow mode (§VI-A step 1): make decisions and log predicted
    /// utility + hypothetical bandwidth, but issue no fills.
    pub shadow: bool,
}

impl Default for ControllerCfg {
    fn default() -> Self {
        ControllerCfg {
            enabled: true,
            threshold: 0.45,
            train_interval_cycles: 2_500_000,
            lr: 0.05,
            epsilon: 0.05,
            adapt_window: false,
            issue_budget_per_kcycle: 0,
            shadow: false,
        }
    }
}

/// A complete single-core simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub hierarchy: HierarchyCfg,
    pub prefetcher: PrefetcherKind,
    /// Controller; `None` = always-issue (the paper's CEIP/EIP baselines).
    pub controller: Option<ControllerCfg>,
    /// Base CPI of a non-stalled core (4-wide issue ≈ 0.25).
    pub base_cpi: f64,
    /// Branch mispredict rate (bad-speculation top-down bucket, Fig 1).
    pub mispredict_rate: f64,
    /// Mispredict penalty in cycles.
    pub mispredict_penalty: f64,
    /// Fraction of D-miss latency exposed (OoO hides the rest).
    pub backend_expose: f64,
    /// Confidence threshold for issuing EIP/selective-CEIP destinations.
    pub conf_threshold: u8,
    pub seed: u64,
    /// Record per-request cycle counts by segmenting the trace on its
    /// `ctx` tag (`SimResult::segments`) — the cluster simulator's
    /// empirical service-time models are fit from these. Observation
    /// only: never perturbs timing, stats, or RNG draws.
    pub track_segments: bool,
    /// Telemetry source for per-context prefetch statistics
    /// (DESIGN.md §12): `"exact"` (default — no sketches allocated,
    /// byte-identical to pre-sketch builds), `"sketch[:GEOM]"`
    /// (controller decision context fed by bounded-memory sketch
    /// estimates), or `"compare[:GEOM]"` (exact decisions plus a
    /// sketch-fed shadow score per decision, for the accuracy report).
    /// GEOM is `w{width}d{depth}p{hll_p}k{topk}`.
    pub telemetry: String,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            hierarchy: HierarchyCfg::table1(),
            prefetcher: PrefetcherKind::NextLineOnly,
            controller: None,
            base_cpi: 0.25,
            mispredict_rate: 0.01,
            mispredict_penalty: 15.0,
            backend_expose: 0.35,
            // Issue destinations as soon as they are learned (conf ≥ 1) —
            // both EIP and whole-window CEIP behave this way; selective
            // modes raise this.
            conf_threshold: 1,
            seed: 1,
            track_segments: false,
            telemetry: "exact".into(),
        }
    }
}

// ---------- JSON (de)serialization ----------

impl SimConfig {
    pub fn to_json(&self) -> Json {
        let h = &self.hierarchy;
        let cache = |c: &CacheCfg| {
            Json::obj(vec![
                ("size_kb", Json::num(c.size_kb as f64)),
                ("ways", Json::num(c.ways as f64)),
                ("line_b", Json::num(c.line_b as f64)),
                ("latency", Json::num(c.latency as f64)),
            ])
        };
        let pf = match &self.prefetcher {
            PrefetcherKind::NextLineOnly => Json::obj(vec![("kind", Json::str("nl"))]),
            PrefetcherKind::Eip { entries } => Json::obj(vec![
                ("kind", Json::str("eip")),
                ("entries", Json::num(*entries as f64)),
            ]),
            PrefetcherKind::Ceip { entries, window, whole_window } => Json::obj(vec![
                ("kind", Json::str("ceip")),
                ("entries", Json::num(*entries as f64)),
                ("window", Json::num(*window as f64)),
                ("whole_window", Json::Bool(*whole_window)),
            ]),
            PrefetcherKind::Cheip { vt_entries, window, whole_window } => Json::obj(vec![
                ("kind", Json::str("cheip")),
                ("vt_entries", Json::num(*vt_entries as f64)),
                ("window", Json::num(*window as f64)),
                ("whole_window", Json::Bool(*whole_window)),
            ]),
            PrefetcherKind::Perfect => Json::obj(vec![("kind", Json::str("perfect"))]),
        };
        let ctrl = match &self.controller {
            None => Json::Null,
            Some(c) => Json::obj(vec![
                ("enabled", Json::Bool(c.enabled)),
                ("threshold", Json::num(c.threshold as f64)),
                ("train_interval_cycles", Json::num(c.train_interval_cycles as f64)),
                ("lr", Json::num(c.lr as f64)),
                ("epsilon", Json::num(c.epsilon)),
                ("adapt_window", Json::Bool(c.adapt_window)),
                ("issue_budget_per_kcycle", Json::num(c.issue_budget_per_kcycle as f64)),
                ("shadow", Json::Bool(c.shadow)),
            ]),
        };
        let mut out = Json::obj(vec![
            (
                "hierarchy",
                Json::obj(vec![
                    ("l1i", cache(&h.l1i)),
                    ("l1d", cache(&h.l1d)),
                    ("l2", cache(&h.l2)),
                    ("l3", cache(&h.l3)),
                    ("dram_latency", Json::num(h.dram_latency as f64)),
                    ("dram_bytes_per_cycle", Json::num(h.dram_bytes_per_cycle)),
                    ("freq_ghz", Json::num(h.freq_ghz)),
                ]),
            ),
            ("prefetcher", pf),
            ("controller", ctrl),
            ("base_cpi", Json::num(self.base_cpi)),
            ("mispredict_rate", Json::num(self.mispredict_rate)),
            ("mispredict_penalty", Json::num(self.mispredict_penalty)),
            ("backend_expose", Json::num(self.backend_expose)),
            ("conf_threshold", Json::num(self.conf_threshold as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("track_segments", Json::Bool(self.track_segments)),
        ]);
        // Emitted only when non-default so existing configs (and
        // anything content-hashing them) serialize byte-identically.
        if self.telemetry != "exact" {
            if let Json::Obj(map) = &mut out {
                map.insert("telemetry".into(), Json::str(&self.telemetry));
            }
        }
        out
    }

    pub fn from_json(j: &Json) -> Result<SimConfig> {
        let mut cfg = SimConfig::default();
        let cache = |j: &Json, name: &str| -> Result<CacheCfg> {
            let c = j.get(name).with_context(|| format!("missing {name}"))?;
            Ok(CacheCfg {
                size_kb: c.get("size_kb").and_then(Json::as_u64).context("size_kb")? as u32,
                ways: c.get("ways").and_then(Json::as_u64).context("ways")? as u32,
                line_b: c.get("line_b").and_then(Json::as_u64).unwrap_or(64) as u32,
                latency: c.get("latency").and_then(Json::as_u64).context("latency")?,
            })
        };
        if let Some(h) = j.get("hierarchy") {
            cfg.hierarchy = HierarchyCfg {
                l1i: cache(h, "l1i")?,
                l1d: cache(h, "l1d")?,
                l2: cache(h, "l2")?,
                l3: cache(h, "l3")?,
                dram_latency: h.get("dram_latency").and_then(Json::as_u64).unwrap_or(90),
                dram_bytes_per_cycle: h
                    .get("dram_bytes_per_cycle")
                    .and_then(Json::as_f64)
                    .unwrap_or(10.24),
                freq_ghz: h.get("freq_ghz").and_then(Json::as_f64).unwrap_or(2.5),
            };
        }
        if let Some(p) = j.get("prefetcher") {
            let kind = p.get("kind").and_then(Json::as_str).context("prefetcher.kind")?;
            let entries = p.get("entries").and_then(Json::as_u64).unwrap_or(256) as u32;
            let window = p.get("window").and_then(Json::as_u64).unwrap_or(8) as u8;
            let whole = p.get("whole_window").and_then(Json::as_bool).unwrap_or(true);
            cfg.prefetcher = match kind {
                "nl" => PrefetcherKind::NextLineOnly,
                "eip" => PrefetcherKind::Eip { entries },
                "ceip" => PrefetcherKind::Ceip { entries, window, whole_window: whole },
                "cheip" => PrefetcherKind::Cheip {
                    vt_entries: p.get("vt_entries").and_then(Json::as_u64).unwrap_or(2048) as u32,
                    window,
                    whole_window: whole,
                },
                "perfect" => PrefetcherKind::Perfect,
                other => bail!("unknown prefetcher kind {other}"),
            };
        }
        match j.get("controller") {
            None | Some(Json::Null) => cfg.controller = None,
            Some(c) => {
                let mut cc = ControllerCfg::default();
                if let Some(v) = c.get("enabled").and_then(Json::as_bool) {
                    cc.enabled = v;
                }
                if let Some(v) = c.get("threshold").and_then(Json::as_f64) {
                    cc.threshold = v as f32;
                }
                if let Some(v) = c.get("train_interval_cycles").and_then(Json::as_u64) {
                    cc.train_interval_cycles = v;
                }
                if let Some(v) = c.get("lr").and_then(Json::as_f64) {
                    cc.lr = v as f32;
                }
                if let Some(v) = c.get("epsilon").and_then(Json::as_f64) {
                    cc.epsilon = v;
                }
                if let Some(v) = c.get("adapt_window").and_then(Json::as_bool) {
                    cc.adapt_window = v;
                }
                if let Some(v) = c.get("issue_budget_per_kcycle").and_then(Json::as_u64) {
                    cc.issue_budget_per_kcycle = v as u32;
                }
                if let Some(v) = c.get("shadow").and_then(Json::as_bool) {
                    cc.shadow = v;
                }
                cfg.controller = Some(cc);
            }
        }
        for (key, dst) in [
            ("base_cpi", &mut cfg.base_cpi),
            ("mispredict_rate", &mut cfg.mispredict_rate),
            ("mispredict_penalty", &mut cfg.mispredict_penalty),
            ("backend_expose", &mut cfg.backend_expose),
        ] {
            if let Some(v) = j.get(key).and_then(Json::as_f64) {
                *dst = v;
            }
        }
        if let Some(v) = j.get("conf_threshold").and_then(Json::as_u64) {
            cfg.conf_threshold = v as u8;
        }
        if let Some(v) = j.get("seed").and_then(Json::as_u64) {
            cfg.seed = v;
        }
        if let Some(v) = j.get("track_segments").and_then(Json::as_bool) {
            cfg.track_segments = v;
        }
        if let Some(v) = j.get("telemetry").and_then(Json::as_str) {
            crate::obs::telemetry::TelemetryCfg::parse(v)?;
            cfg.telemetry = v.to_string();
        }
        Ok(cfg)
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty()).with_context(|| format!("write {path:?}"))
    }

    pub fn load(path: &std::path::Path) -> Result<SimConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("read {path:?}"))?;
        Self::from_json(&Json::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let h = HierarchyCfg::table1();
        assert_eq!(h.l1i.lines(), 512); // §V: "512 lines"
        assert_eq!(h.l1i.sets(), 64);
        assert_eq!(h.l1d.size_kb, 48);
        assert_eq!(h.l1d.ways, 12);
        assert_eq!(h.l2.latency, 15);
        assert_eq!(h.l3.latency, 35);
        assert_eq!(h.l3.size_kb, 2048);
        assert!((h.dram_bytes_per_cycle - 10.24).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip_all_prefetchers() {
        for pf in [
            PrefetcherKind::NextLineOnly,
            PrefetcherKind::Eip { entries: 128 },
            PrefetcherKind::Ceip { entries: 256, window: 8, whole_window: true },
            PrefetcherKind::Cheip { vt_entries: 4096, window: 12, whole_window: false },
            PrefetcherKind::Perfect,
        ] {
            let mut cfg = SimConfig::default();
            cfg.prefetcher = pf.clone();
            cfg.controller = Some(ControllerCfg::default());
            cfg.seed = 99;
            let back = SimConfig::from_json(&cfg.to_json()).unwrap();
            assert_eq!(back.prefetcher, pf);
            assert_eq!(back.seed, 99);
            assert_eq!(back.controller, cfg.controller);
            assert_eq!(back.hierarchy, cfg.hierarchy);
        }
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(PrefetcherKind::Eip { entries: 256 }.label(), "eip256");
        assert_eq!(
            PrefetcherKind::Ceip { entries: 128, window: 8, whole_window: true }.label(),
            "ceip128w8"
        );
        assert_eq!(
            PrefetcherKind::Cheip { vt_entries: 2048, window: 8, whole_window: false }.label(),
            "cheip2048w8s"
        );
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("slofetch_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.json");
        let mut cfg = SimConfig::default();
        cfg.prefetcher = PrefetcherKind::Eip { entries: 64 };
        cfg.save(&path).unwrap();
        let back = SimConfig::load(&path).unwrap();
        assert_eq!(back.prefetcher, cfg.prefetcher);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn telemetry_knob_roundtrips_and_defaults_serialize_unchanged() {
        // Default ("exact") emits no key at all — pre-sketch configs and
        // their content hashes are untouched.
        let cfg = SimConfig::default();
        assert!(!cfg.to_json().dump().contains("telemetry"));
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.telemetry, "exact");
        // Non-default round-trips.
        let mut cfg = SimConfig::default();
        cfg.telemetry = "compare:w128d4p10k16".into();
        assert!(cfg.to_json().dump().contains("\"telemetry\":\"compare:w128d4p10k16\""));
        let back = SimConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(back.telemetry, cfg.telemetry);
        // Garbage knobs are rejected at load time.
        let j = Json::parse(r#"{"telemetry": "psychic"}"#).unwrap();
        assert!(SimConfig::from_json(&j).is_err());
    }

    #[test]
    fn rejects_unknown_prefetcher() {
        let j = Json::parse(r#"{"prefetcher": {"kind": "bogus"}}"#).unwrap();
        assert!(SimConfig::from_json(&j).is_err());
    }
}
