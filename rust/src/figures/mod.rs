//! Figure/table regeneration harness: one driver per table and figure in
//! the paper's evaluation (DESIGN.md §5 experiment index). Shared runs are
//! computed once in a [`Matrix`] (11 apps × 8 prefetcher configs via the
//! campaign runner) and every figure reads from it.
//!
//! Absolute numbers differ from the paper (synthetic traces, analytic
//! timing — §X-D's caveat applies doubly); the *shape* assertions live in
//! `rust/tests/integration_figures.rs`.

pub mod report;
pub mod schematics;

use crate::campaign::runner::{run_cells, Cell};
use crate::config::{ControllerCfg, HierarchyCfg, PrefetcherKind, SimConfig};
use crate::sim::engine::SimResult;
use crate::trace::gen::apps::{self, AppSpec};
use report::{f2, f3, kb, pct, Table};
use std::collections::HashMap;

/// Experiment-scale knobs.
#[derive(Clone, Debug)]
pub struct FigureCtx {
    pub records_per_app: u64,
    pub seed: u64,
    pub parallelism: usize,
    pub out_dir: Option<std::path::PathBuf>,
}

impl Default for FigureCtx {
    fn default() -> Self {
        FigureCtx {
            records_per_app: 600_000,
            seed: 7,
            parallelism: crate::campaign::runner::default_threads(),
            out_dir: Some(std::path::PathBuf::from("results")),
        }
    }
}

impl FigureCtx {
    /// Small-scale context for tests.
    pub fn quick() -> Self {
        FigureCtx {
            records_per_app: 60_000,
            out_dir: None,
            ..Default::default()
        }
    }
}

/// The standard config set every figure draws from. "128"/"256" follow the
/// paper's set-count naming: K sets × 16 ways.
pub fn standard_configs() -> Vec<(&'static str, PrefetcherKind)> {
    vec![
        ("nl", PrefetcherKind::NextLineOnly),
        ("eip128", PrefetcherKind::Eip { entries: 128 * 16 }),
        ("eip256", PrefetcherKind::Eip { entries: 256 * 16 }),
        (
            "ceip128",
            PrefetcherKind::Ceip { entries: 128 * 16, window: 8, whole_window: true },
        ),
        (
            "ceip256",
            PrefetcherKind::Ceip { entries: 256 * 16, window: 8, whole_window: true },
        ),
        (
            "cheip2k",
            PrefetcherKind::Cheip { vt_entries: 2048, window: 8, whole_window: true },
        ),
        (
            "cheip4k",
            PrefetcherKind::Cheip { vt_entries: 4096, window: 8, whole_window: true },
        ),
        ("perfect", PrefetcherKind::Perfect),
    ]
}

/// All (app × config) results, computed once.
pub struct Matrix {
    pub ctx: FigureCtx,
    pub apps: Vec<AppSpec>,
    /// (app name, config name) → result.
    results: HashMap<(String, String), SimResult>,
}

impl Matrix {
    /// Run the full matrix (sharded across cells by the campaign runner).
    pub fn compute(ctx: FigureCtx) -> Matrix {
        let apps = apps::all_apps();
        let mut cells = Vec::new();
        let mut keys = Vec::new();
        for app in &apps {
            for (name, kind) in standard_configs() {
                keys.push((app.name.to_string(), name.to_string()));
                cells.push(Cell {
                    app: app.clone(),
                    label: name.to_string(),
                    cfg: SimConfig {
                        prefetcher: kind,
                        seed: ctx.seed,
                        ..Default::default()
                    },
                    records: ctx.records_per_app,
                    trace_seed: ctx.seed,
                    trace: None,
                });
            }
        }
        let outputs = run_cells(&cells, ctx.parallelism);
        let mut results = HashMap::new();
        for (key, result) in keys.into_iter().zip(outputs) {
            results.insert(key, result);
        }
        Matrix { ctx, apps, results }
    }

    pub fn get(&self, app: &str, config: &str) -> &SimResult {
        self.results
            .get(&(app.to_string(), config.to_string()))
            .unwrap_or_else(|| panic!("no result for ({app}, {config})"))
    }

    /// Speedup of `config` over the NL baseline for `app`.
    pub fn speedup(&self, app: &str, config: &str) -> f64 {
        self.get(app, config).ipc() / self.get(app, "nl").ipc()
    }

    /// Geometric-mean speedup across apps.
    pub fn geomean_speedup(&self, config: &str) -> f64 {
        let logs: f64 = self
            .apps
            .iter()
            .map(|a| self.speedup(a.name, config).ln())
            .sum();
        (logs / self.apps.len() as f64).exp()
    }

    fn app_names(&self) -> Vec<&'static str> {
        self.apps.iter().map(|a| a.name).collect()
    }
}

// ---------------------------------------------------------------- figures

/// Table I: the simulated system.
pub fn table1() -> Table {
    let h = HierarchyCfg::table1();
    let mut t = Table::new("table1", "Simulated system", &["Parameter", "Values"]);
    t.row(vec!["CPU frequency".into(), format!("{} GHz", h.freq_ghz)]);
    let cache = |c: &crate::config::CacheCfg| {
        format!("{} KB, {}-way, {}-cycle", c.size_kb, c.ways, c.latency)
    };
    t.row(vec!["L1 I cache".into(), cache(&h.l1i)]);
    t.row(vec!["L1 D cache".into(), format!("{} with NLP", cache(&h.l1d))]);
    t.row(vec!["L2 cache".into(), cache(&h.l2)]);
    t.row(vec!["L3 cache".into(), cache(&h.l3)]);
    t.row(vec![
        "DRAM".into(),
        format!(
            "1 channel, {:.1} GB/s, {}-cycle access",
            h.dram_bytes_per_cycle * h.freq_ghz,
            h.dram_latency
        ),
    ]);
    t
}

/// Fig 1: top-down breakdown on the web-search binary (NL baseline).
pub fn fig1(m: &Matrix) -> Table {
    let mut t = Table::new(
        "fig1",
        "Top-down performance breakdown (websearch)",
        &["bucket", "share"],
    );
    let r = m.get("websearch", "nl");
    let f = r.stats.topdown.fractions();
    for (name, v) in [("retiring", f[0]), ("frontend", f[1]), ("backend", f[2]), ("bad_spec", f[3])]
    {
        t.row(vec![name.into(), pct(v)]);
    }
    t.note("paper: frontend stalls are a leading bucket on web search");
    t
}

/// Fig 2: instruction MPKI across the eleven applications.
pub fn fig2(m: &Matrix) -> Table {
    let mut t = Table::new(
        "fig2",
        "Instruction MPKI across eleven applications (NL baseline)",
        &["app", "I-MPKI", "L1D-MPKI"],
    );
    for app in m.app_names() {
        let r = m.get(app, "nl");
        t.row(vec![app.into(), f2(r.stats.mpki()), f2(r.stats.l1d_mpki())]);
    }
    t.note("paper shape: managed-runtime + deep-stack services highest; crypto lowest");
    t
}

/// Fig 6: EIP vs a perfect prefetcher.
pub fn fig6(m: &Matrix) -> Table {
    let mut t = Table::new(
        "fig6",
        "EIP versus a perfect prefetcher (speedup over NL)",
        &["app", "eip256", "perfect", "gap"],
    );
    for app in m.app_names() {
        let e = m.speedup(app, "eip256");
        let p = m.speedup(app, "perfect");
        t.row(vec![app.into(), f3(e), f3(p), f3(p - e)]);
    }
    t.row(vec![
        "geomean".into(),
        f3(m.geomean_speedup("eip256")),
        f3(m.geomean_speedup("perfect")),
        "".into(),
    ]);
    t.note("paper: capacity limits coverage — EIP leaves a gap to the oracle");
    t
}

/// Fig 7: share of entangled pairs whose delta fits in 20 bits.
pub fn fig7(m: &Matrix) -> Table {
    let mut t = Table::new(
        "fig7",
        "Share of pairs within a 20-bit delta",
        &["app", "fit20"],
    );
    for app in m.app_names() {
        let ps = m.get(app, "ceip256").pair_stats;
        t.row(vec![app.into(), pct(ps.fit20_frac())]);
    }
    t.note("paper: deltas overwhelmingly fall within 20 bits; managed runtimes lower");
    t
}

/// Fig 8: share of destinations covered by an 8-line window.
pub fn fig8(m: &Matrix) -> Table {
    let mut t = Table::new(
        "fig8",
        "Share of destinations covered within an 8-line window",
        &["app", "covered"],
    );
    for app in m.app_names() {
        let ps = m.get(app, "eip256").pair_stats;
        t.row(vec![app.into(), pct(ps.window_frac())]);
    }
    t.note("measured over the uncompressed EIP table: best 8-line window per destination set");
    t
}

/// Fig 9: speedup of CEIP and EIP at both table scales.
pub fn fig9(m: &Matrix) -> Table {
    let mut t = Table::new(
        "fig9",
        "Speedup of CEIP and EIP (over NL baseline)",
        &["app", "eip128", "ceip128", "eip256", "ceip256"],
    );
    for app in m.app_names() {
        t.row(vec![
            app.into(),
            f3(m.speedup(app, "eip128")),
            f3(m.speedup(app, "ceip128")),
            f3(m.speedup(app, "eip256")),
            f3(m.speedup(app, "ceip256")),
        ]);
    }
    t.row(vec![
        "geomean".into(),
        f3(m.geomean_speedup("eip128")),
        f3(m.geomean_speedup("ceip128")),
        f3(m.geomean_speedup("eip256")),
        f3(m.geomean_speedup("ceip256")),
    ]);
    // "X% below in speedup" = percentage points of speedup (§X-C).
    let d256 = (m.geomean_speedup("eip256") - m.geomean_speedup("ceip256")) * 100.0;
    let d128 = (m.geomean_speedup("eip128") - m.geomean_speedup("ceip128")) * 100.0;
    t.note(&format!(
        "paper §X-C: CEIP-256 is on average 2.3% below EIP-256 in speedup, \
         CEIP-128 2.0% below EIP-128. measured: {d256:.1}pp / {d128:.1}pp"
    ));
    t
}

/// Fig 10: relative speedup reduction vs uncovered destinations.
pub fn fig10(m: &Matrix) -> Table {
    let mut t = Table::new(
        "fig10",
        "Relative reduction in speedup versus uncovered destinations",
        &["app", "uncovered", "speedup_reduction"],
    );
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for app in m.app_names() {
        let uncovered = m.get(app, "ceip256").pair_stats.uncovered_frac();
        let eip = m.speedup(app, "eip256") - 1.0;
        let ceip = m.speedup(app, "ceip256") - 1.0;
        let reduction = if eip > 1e-6 { ((eip - ceip) / eip).max(-1.0) } else { 0.0 };
        xs.push(uncovered);
        ys.push(reduction);
        t.row(vec![app.into(), pct(uncovered), pct(reduction)]);
    }
    let r = pearson(&xs, &ys);
    t.note(&format!(
        "paper: the reduction closely follows the excluded-destination fraction; \
         Pearson r = {r:.2}"
    ));
    t
}

/// Fig 11: MPKI reduction relative to the NL baseline.
pub fn fig11(m: &Matrix) -> Table {
    let mut t = Table::new(
        "fig11",
        "MPKI reduction (vs NL baseline)",
        &["app", "eip256", "ceip256", "cheip2k", "cheip4k"],
    );
    for app in m.app_names() {
        let base = m.get(app, "nl").stats.mpki();
        let red = |cfg: &str| {
            let v = m.get(app, cfg).stats.mpki();
            if base > 0.0 {
                pct((base - v) / base)
            } else {
                "n/a".into()
            }
        };
        t.row(vec![
            app.into(),
            red("eip256"),
            red("ceip256"),
            red("cheip2k"),
            red("cheip4k"),
        ]);
    }
    t.note("paper: MPKI reductions remain strong under compression; virtualization adds L1-side metadata");
    t
}

/// Fig 12: prefetch accuracy.
pub fn fig12(m: &Matrix) -> Table {
    let mut t = Table::new(
        "fig12",
        "Prefetch accuracy",
        &["app", "eip256", "ceip256", "cheip2k"],
    );
    let mut eip_sum = 0.0;
    let mut ceip_sum = 0.0;
    for app in m.app_names() {
        let e = m.get(app, "eip256").stats.accuracy();
        let c = m.get(app, "ceip256").stats.accuracy();
        let h = m.get(app, "cheip2k").stats.accuracy();
        eip_sum += e;
        ceip_sum += c;
        t.row(vec![app.into(), pct(e), pct(c), pct(h)]);
    }
    let n = m.apps.len() as f64;
    t.note(&format!(
        "paper: CEIP improves accuracy by concentrating on dense regions — mean {} vs {}",
        pct(ceip_sum / n),
        pct(eip_sum / n)
    ));
    t
}

/// Fig 13: storage versus speedup.
pub fn fig13(m: &Matrix) -> Table {
    let mut t = Table::new(
        "fig13",
        "Storage versus speedup",
        &["config", "on-chip state", "geomean speedup"],
    );
    for cfg in ["eip128", "eip256", "ceip128", "ceip256", "cheip2k", "cheip4k"] {
        // Metadata bytes are identical across apps; take the first.
        let bytes = m.get(m.app_names()[0], cfg).metadata_bytes;
        t.row(vec![cfg.into(), kb(bytes), f3(m.geomean_speedup(cfg))]);
    }
    t.note("paper: CEIP/CHEIP preserve EIP-like speedups at a fraction of the state");
    t
}

/// §X-C headline summary (the end-to-end validation record).
pub fn summary(m: &Matrix) -> Table {
    let mut t = Table::new(
        "summary",
        "Headline claims (paper §X-C ↔ measured)",
        &["claim", "paper", "measured"],
    );
    let gm = |c: &str| m.geomean_speedup(c);
    let deficit_pp = |ceip: f64, eip: f64| (eip - ceip) * 100.0;
    t.row(vec![
        "CEIP-256 below EIP-256 in speedup".into(),
        "~2.3%".into(),
        format!("{:.1}pp", deficit_pp(gm("ceip256"), gm("eip256"))),
    ]);
    t.row(vec![
        "CEIP-128 below EIP-128 in speedup".into(),
        "~2.0%".into(),
        format!("{:.1}pp", deficit_pp(gm("ceip128"), gm("eip128"))),
    ]);
    let acc = |cfg: &str| {
        m.apps
            .iter()
            .map(|a| m.get(a.name, cfg).stats.accuracy())
            .sum::<f64>()
            / m.apps.len() as f64
    };
    t.row(vec![
        "CEIP accuracy vs EIP".into(),
        "higher".into(),
        format!("{} vs {}", pct(acc("ceip256")), pct(acc("eip256"))),
    ]);
    t.row(vec![
        "CHEIP-2K total metadata".into(),
        "24.75 KB".into(),
        kb(m.get("websearch", "cheip2k").metadata_bytes),
    ]);
    t.row(vec![
        "CHEIP-4K total metadata".into(),
        "46.5 KB".into(),
        kb(m.get("websearch", "cheip4k").metadata_bytes),
    ]);
    t.row(vec![
        "CHEIP speedup vs CEIP (virtualization preserved)".into(),
        "≈ preserved".into(),
        format!("{} vs {}", f3(gm("cheip4k")), f3(gm("ceip256"))),
    ]);
    t
}

/// Ablations (§IX window sensitivity, §XIII whole-vs-selective, controller).
pub fn ablation(ctx: &FigureCtx) -> Table {
    let apps_sel = ["websearch", "retail-java", "admission"];
    let mut cells = Vec::new();
    let mut labels = Vec::new();
    let variants: Vec<(String, PrefetcherKind, Option<ControllerCfg>)> = vec![
        ("nl".into(), PrefetcherKind::NextLineOnly, None),
        (
            "w4".into(),
            PrefetcherKind::Ceip { entries: 4096, window: 4, whole_window: true },
            None,
        ),
        (
            "w8".into(),
            PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true },
            None,
        ),
        (
            "w12".into(),
            PrefetcherKind::Ceip { entries: 4096, window: 12, whole_window: true },
            None,
        ),
        (
            "w8-selective".into(),
            PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: false },
            None,
        ),
        (
            "w8+ml".into(),
            PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true },
            Some(ControllerCfg {
                train_interval_cycles: 200_000,
                ..Default::default()
            }),
        ),
        (
            "w12+ml-adapt".into(),
            PrefetcherKind::Ceip { entries: 4096, window: 12, whole_window: true },
            Some(ControllerCfg {
                adapt_window: true,
                train_interval_cycles: 200_000,
                ..Default::default()
            }),
        ),
    ];
    for app in apps_sel {
        for (label, kind, ctrl) in &variants {
            labels.push((app.to_string(), label.clone()));
            cells.push(Cell {
                app: apps::app(app).unwrap(),
                label: label.clone(),
                cfg: SimConfig {
                    prefetcher: kind.clone(),
                    controller: ctrl.clone(),
                    seed: ctx.seed,
                    ..Default::default()
                },
                records: ctx.records_per_app,
                trace_seed: ctx.seed,
                trace: None,
            });
        }
    }
    let outputs = run_cells(&cells, ctx.parallelism);
    let mut by_key: HashMap<(String, String), SimResult> = HashMap::new();
    for (key, result) in labels.into_iter().zip(outputs) {
        by_key.insert(key, result);
    }
    let mut t = Table::new(
        "ablation",
        "Window size / policy / controller ablations (speedup over NL; accuracy)",
        &["app", "variant", "speedup", "accuracy", "issued/ki", "skipped"],
    );
    for app in apps_sel {
        let nl_ipc = by_key[&(app.to_string(), "nl".to_string())].ipc();
        for (label, _, _) in &variants {
            if label == "nl" {
                continue;
            }
            let r = &by_key[&(app.to_string(), label.clone())];
            let ki = r.stats.instrs as f64 / 1000.0;
            t.row(vec![
                app.into(),
                label.clone(),
                f3(r.ipc() / nl_ipc),
                pct(r.stats.accuracy()),
                f2(r.stats.pf_issued as f64 / ki),
                r.stats.pf_skipped.to_string(),
            ]);
        }
    }
    t.note("paper §IX: window 8 balances coverage/accuracy; whole-window beats selective (§XIII); ML gate trades issue volume for accuracy");
    t
}

/// Control-plane RPC tail latencies per prefetcher (§XI), computed on
/// the cluster event-loop engine with the linear chain as the degenerate
/// request DAG (DESIGN.md §4/§8). The legacy tandem recursion in `rpc/`
/// remains as the analytic cross-check of this special case.
pub fn rpc_tails(m: &Matrix) -> Table {
    use crate::cluster::{engine as cluster_engine, ResolvedTopology, RunParams, TrafficShape};
    let mut t = Table::new(
        "rpc",
        "Control-plane RPC latency (admission→featurestore→mlserve chain, 65% util)",
        &["config", "P50 µs", "P95 µs", "P99 µs", "P99/P50"],
    );
    let chain_ipcs = |cfg: &str| -> Vec<(String, f64)> {
        vec![
            ("admission".into(), m.get("admission", cfg).ipc()),
            ("featurestore".into(), m.get("featurestore-go", cfg).ipc()),
            ("mlserve".into(), m.get("mlserve", cfg).ipc()),
        ]
    };
    // Fixed absolute arrival rate across configs (the NL bottleneck at
    // 65%), so faster configs see lower utilization — the operational
    // win the paper describes (§XI).
    let nl_topo = ResolvedTopology::chain_from_ipcs(&chain_ipcs("nl"), 25_000.0, 0.35, 2.5);
    let lambda = nl_topo.bottleneck_rate() * 0.65;
    for cfg in ["nl", "eip256", "ceip256", "cheip2k", "perfect"] {
        let topo = ResolvedTopology::chain_from_ipcs(&chain_ipcs(cfg), 25_000.0, 0.35, 2.5);
        let r = cluster_engine::run(
            &topo,
            &TrafficShape::Poisson { util: 1.0 },
            &RunParams {
                requests: 40_000,
                seed: 17,
                slo_us: f64::INFINITY,
                base_rate_per_us: lambda,
            },
            None,
        )
        .expect("rpc chain parameters are statically valid");
        t.row(vec![
            cfg.into(),
            f2(r.p50_us),
            f2(r.p95_us),
            f2(r.p99_us),
            f2(r.p99_us / r.p50_us),
        ]);
    }
    t.note("paper: single-digit IPC gains compound into P95/P99 reductions at fixed load");
    t
}

fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let n = xs.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let vx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let vy: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx * vy).sqrt()
}

/// Run every figure; returns all tables (and writes them to `ctx.out_dir`).
pub fn all(ctx: FigureCtx) -> anyhow::Result<Vec<Table>> {
    let out_dir = ctx.out_dir.clone();
    let m = Matrix::compute(ctx.clone());
    let mut tables = vec![
        table1(),
        fig1(&m),
        fig2(&m),
        schematics::fig3(),
        schematics::fig4(),
        schematics::fig5(),
        fig6(&m),
        fig7(&m),
        fig8(&m),
        fig9(&m),
        fig10(&m),
        fig11(&m),
        fig12(&m),
        fig13(&m),
        summary(&m),
        rpc_tails(&m),
    ];
    tables.push(ablation(&ctx));
    if let Some(dir) = out_dir {
        for t in &tables {
            t.save(&dir)?;
        }
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_text() {
        let t = table1();
        let md = t.markdown();
        assert!(md.contains("2.5 GHz"));
        assert!(md.contains("32 KB, 8-way, 4-cycle"));
        assert!(md.contains("48 KB, 12-way, 5-cycle with NLP"));
        assert!(md.contains("2048 KB, 16-way, 35-cycle"));
        assert!(md.contains("25.6 GB/s"));
    }

    #[test]
    fn pearson_basics() {
        let xs = [1.0, 2.0, 3.0];
        assert!((pearson(&xs, &[2.0, 4.0, 6.0]) - 1.0).abs() < 1e-9);
        assert!((pearson(&xs, &[3.0, 2.0, 1.0]) + 1.0).abs() < 1e-9);
        assert_eq!(pearson(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn standard_configs_cover_paper_variants() {
        let names: Vec<&str> = standard_configs().iter().map(|(n, _)| *n).collect();
        for want in ["nl", "eip128", "eip256", "ceip128", "ceip256", "cheip2k", "cheip4k", "perfect"]
        {
            assert!(names.contains(&want), "{want} missing");
        }
    }
}
