//! Report tables: markdown + JSON rendering for every figure/table the
//! harness regenerates, so EXPERIMENTS.md entries are copy-paste
//! reproducible.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Table {
    pub id: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (expected paper shape, caveats).
    pub notes: Vec<String>,
}

impl Table {
    pub fn new(id: &str, title: &str, headers: &[&str]) -> Self {
        Table {
            id: id.to_string(),
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
        self
    }

    pub fn note(&mut self, n: &str) -> &mut Self {
        self.notes.push(n.to_string());
        self
    }

    /// Render as GitHub-flavored markdown.
    pub fn markdown(&self) -> String {
        let mut out = format!("### {} — {}\n\n", self.id, self.title);
        let widths: Vec<usize> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain(std::iter::once(h.len()))
                    .max()
                    .unwrap_or(3)
            })
            .collect();
        let fmt_row = |cells: &[String]| {
            let padded: Vec<String> = cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect();
            format!("| {} |\n", padded.join(" | "))
        };
        out.push_str(&fmt_row(&self.headers));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        for n in &self.notes {
            out.push_str(&format!("\n> {n}\n"));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(&self.id)),
            ("title", Json::str(&self.title)),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::str(h)).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::str(c)).collect()))
                        .collect(),
                ),
            ),
            (
                "notes",
                Json::Arr(self.notes.iter().map(|n| Json::str(n)).collect()),
            ),
        ])
    }

    /// Write `<dir>/<id>.md` and `<dir>/<id>.json`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
        std::fs::write(dir.join(format!("{}.md", self.id)), self.markdown())?;
        std::fs::write(dir.join(format!("{}.json", self.id)), self.to_json().pretty())?;
        Ok(())
    }
}

/// Format helpers shared by figure drivers.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

pub fn kb(bytes: u64) -> String {
    format!("{:.2} KB", bytes as f64 / 1024.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_aligned() {
        let mut t = Table::new("fig9", "Speedup", &["app", "eip256"]);
        t.row(vec!["websearch".into(), "1.043".into()]);
        t.note("expected: CEIP ~2% below EIP");
        let md = t.markdown();
        assert!(md.contains("### fig9"));
        assert!(md.contains("| websearch | 1.043  |"));
        assert!(md.contains("> expected"));
    }

    #[test]
    fn json_roundtrips() {
        let mut t = Table::new("x", "y", &["a"]);
        t.row(vec!["1".into()]);
        let j = t.to_json();
        assert_eq!(j.path(&["rows"]).unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("slofetch_report_test");
        let mut t = Table::new("t1", "test", &["c"]);
        t.row(vec!["v".into()]);
        t.save(&dir).unwrap();
        assert!(dir.join("t1.md").exists());
        assert!(dir.join("t1.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn format_helpers() {
        assert_eq!(f2(1.2345), "1.23");
        assert_eq!(pct(0.123), "12.3%");
        assert_eq!(kb(25200), "24.61 KB");
    }
}
