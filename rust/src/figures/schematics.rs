//! Figs 3–5 are schematics in the paper; we regenerate them as ASCII
//! diagrams rendered *from live data structures* (not static strings
//! pasted in): Fig 4's bit layout comes from an actual packed [`CEntry`].

use super::report::Table;
use crate::prefetch::centry::CEntry;

/// Fig 3: timeliness — late arrivals vs early pollution.
pub fn fig3() -> Table {
    let mut t = Table::new(
        "fig3",
        "Timely prefetching avoids late arrivals and early pollution",
        &["scenario", "timeline"],
    );
    t.row(vec![
        "late".into(),
        "issue ──────▶ fill".into(),
    ]);
    t.row(vec![
        "".into(),
        "          demand ✖ (stalls for residual)".into(),
    ]);
    t.row(vec![
        "timely".into(),
        "issue ──▶ fill ···· demand ✔ (hit)".into(),
    ]);
    t.row(vec![
        "early".into(),
        "issue ▶ fill ·········(evicted)···· demand ✖ (pollution)".into(),
    ]);
    t
}

/// Fig 4: the compressed 36-bit destination encoding, from a live entry.
pub fn fig4() -> Table {
    // Build a real entry and show its packed layout.
    let src: u64 = 0x0040_1000;
    let mut e = CEntry::new(8, src + 0x64);
    e.mark(src, src + 0x66);
    e.mark(src, src + 0x66);
    e.mark(src, src + 0x69);
    let packed = e.pack();
    let mut t = Table::new(
        "fig4",
        "Compressed destination encoding: 20-bit base + eight 2-bit confidences (36 bits)",
        &["field", "bits", "value"],
    );
    t.row(vec![
        "base (LSBs of destination window)".into(),
        "[19:0]".into(),
        format!("0x{:05x}", packed & 0xF_FFFF),
    ]);
    for off in 0..8u32 {
        let c = (packed >> (20 + 2 * off)) & 0b11;
        t.row(vec![
            format!("confidence, offset {off}"),
            format!("[{}:{}]", 21 + 2 * off, 20 + 2 * off),
            format!("{c}"),
        ]);
    }
    t.note(&format!(
        "total = {} bits; packed value 0x{packed:09x} (round-trips via CEntry::unpack)",
        CEntry::storage_bits(8)
    ));
    t
}

/// Fig 5: the CHEIP hierarchy.
pub fn fig5() -> Table {
    let mut t = Table::new(
        "fig5",
        "CHEIP hierarchy: L1-attached entries + virtualized entangle table",
        &["level", "metadata"],
    );
    t.row(vec![
        "L1-I (32 KB, 512 lines)".into(),
        "1 compressed entry / line = 2304 B, queried at L1 speed".into(),
    ]);
    t.row(vec![
        "  ⇅ migrate with line fill/evict".into(),
        "(pays L2-class latency on fill)".into(),
    ]);
    t.row(vec![
        "L2/L3 (virtualized table)".into(),
        "16-way, 2K/4K entries × (51b tag + 36b payload) = 21.75/43.5 KB".into(),
    ]);
    t.row(vec![
        "history buffer".into(),
        "64 × (58b tag + 20b ts) = 624 B".into(),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_is_36_bits_and_live() {
        let t = fig4();
        assert_eq!(t.rows.len(), 9); // base + 8 confidences
        assert!(t.notes[0].contains("36 bits"));
    }

    #[test]
    fn schematics_render() {
        for t in [fig3(), fig5()] {
            assert!(!t.markdown().is_empty());
        }
    }
}
