//! Contextual bandit for the decision threshold and (optionally) the
//! prefetch window size (paper §IV-B): ε-greedy over a small per-context
//! action-value table, updated incrementally with the shaped reward
//! (future hits minus eviction/useless-fill penalties over a short
//! horizon). "Fast, monotone adaptations" — the value update is
//! v ← v + lr·(r − v), the same math as the AOT `bandit.hlo.txt` module.

use crate::util::rng::Rng;

/// Candidate thresholds the bandit arbitrates between.
pub const THRESHOLDS: [f32; 4] = [0.30, 0.45, 0.60, 0.75];
/// Window-size arms (§IV-B: "optionally choose among window sizes
/// {4, 8, 12}").
pub const WINDOWS: [u8; 3] = [4, 8, 12];
/// Context buckets: (density-high, headroom-high, short-loop) → 8.
pub const CONTEXTS: usize = 8;

/// Flattened value-table sizes (threshold table then window table) — the
/// AOT bandit module operates on the concatenation (64 slots, padded).
pub const THRESHOLD_SLOTS: usize = CONTEXTS * THRESHOLDS.len(); // 32
pub const WINDOW_SLOTS: usize = CONTEXTS * WINDOWS.len(); // 24
pub const TOTAL_SLOTS: usize = 64; // matches python aot BANDIT_SLOTS

/// Context bucket from decision-time signals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Context(pub usize);

impl Context {
    pub fn from_signals(density_high: bool, headroom_high: bool, short_loop: bool) -> Self {
        Context((density_high as usize) | (headroom_high as usize) << 1 | (short_loop as usize) << 2)
    }
}

#[derive(Clone, Debug)]
pub struct Bandit {
    /// Concatenated value tables, padded to [`TOTAL_SLOTS`].
    pub values: [f32; TOTAL_SLOTS],
    pub epsilon: f64,
    pub lr: f32,
    rng: Rng,
    /// Pulls per slot (diagnostics / tests).
    pub pulls: [u32; TOTAL_SLOTS],
}

impl Bandit {
    pub fn new(epsilon: f64, lr: f32, seed: u64) -> Self {
        Bandit {
            // Optimistic initialization encourages early exploration.
            values: [0.5; TOTAL_SLOTS],
            epsilon,
            lr,
            rng: Rng::new(seed),
            pulls: [0; TOTAL_SLOTS],
        }
    }

    fn pick(&mut self, base: usize, n: usize) -> usize {
        let arm = if self.rng.chance(self.epsilon) {
            self.rng.below(n as u64) as usize
        } else {
            // total_cmp, not partial_cmp().unwrap(): a NaN value (e.g.
            // from a poisoned external table via set_values) must pick
            // some arm, not panic mid-run.
            (0..n)
                .max_by(|&a, &b| self.values[base + a].total_cmp(&self.values[base + b]))
                .unwrap()
        };
        self.pulls[base + arm] += 1;
        arm
    }

    /// Choose the decision threshold for this context. Returns
    /// (threshold, slot index for the later reward update).
    pub fn choose_threshold(&mut self, ctx: Context) -> (f32, usize) {
        let base = ctx.0 * THRESHOLDS.len();
        let arm = self.pick(base, THRESHOLDS.len());
        (THRESHOLDS[arm], base + arm)
    }

    /// Choose the effective window size. Returns (window, slot index).
    pub fn choose_window(&mut self, ctx: Context) -> (u8, usize) {
        let base = THRESHOLD_SLOTS + ctx.0 * WINDOWS.len();
        let arm = self.pick(base, WINDOWS.len());
        (WINDOWS[arm], base + arm)
    }

    /// Choose among the first `n` arms of this context's threshold table
    /// for an external decision (the cluster SLO control loop arbitrates
    /// config-switch vs. scale-out this way, reusing the same value
    /// table and update rule). Returns (arm index, slot index).
    pub fn choose_arm(&mut self, ctx: Context, n: usize) -> (usize, usize) {
        let n = n.clamp(1, THRESHOLDS.len());
        let base = ctx.0 * THRESHOLDS.len();
        let arm = self.pick(base, n);
        (arm, base + arm)
    }

    /// Incremental value update: v ← v + lr·(r − v). Mirrors the AOT
    /// bandit module; the coordinator can route this through PJRT.
    pub fn update(&mut self, slot: usize, reward: f32) {
        let v = self.values[slot];
        self.values[slot] = v + self.lr * (reward - v);
    }

    /// Apply an externally-computed value table (PJRT path).
    pub fn set_values(&mut self, values: &[f32]) {
        self.values[..values.len().min(TOTAL_SLOTS)]
            .copy_from_slice(&values[..values.len().min(TOTAL_SLOTS)]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_buckets_are_distinct() {
        let mut seen = std::collections::HashSet::new();
        for d in [false, true] {
            for h in [false, true] {
                for s in [false, true] {
                    seen.insert(Context::from_signals(d, h, s).0);
                }
            }
        }
        assert_eq!(seen.len(), 8);
        assert!(seen.iter().all(|&c| c < CONTEXTS));
    }

    #[test]
    fn converges_to_best_threshold_arm() {
        let mut b = Bandit::new(0.1, 0.2, 42);
        let ctx = Context(3);
        // Reward structure: arm 1 (threshold 0.45) is best.
        for _ in 0..2000 {
            let (t, slot) = b.choose_threshold(ctx);
            let r = if (t - 0.45).abs() < 1e-6 { 1.0 } else { 0.1 };
            b.update(slot, r);
        }
        let base = ctx.0 * THRESHOLDS.len();
        let best =
            (0..4).max_by(|&a, &c| b.values[base + a].total_cmp(&b.values[base + c])).unwrap();
        assert_eq!(best, 1, "values: {:?}", &b.values[base..base + 4]);
        // Greedy pulls concentrate on the best arm.
        assert!(b.pulls[base + 1] > 1000);
    }

    #[test]
    fn window_arm_selection_in_range() {
        let mut b = Bandit::new(0.5, 0.1, 7);
        for _ in 0..100 {
            let (w, slot) = b.choose_window(Context(5));
            assert!(WINDOWS.contains(&w));
            assert!((THRESHOLD_SLOTS..THRESHOLD_SLOTS + WINDOW_SLOTS).contains(&slot));
        }
    }

    #[test]
    fn update_moves_toward_reward() {
        let mut b = Bandit::new(0.0, 0.5, 1);
        b.update(0, 1.0);
        assert!((b.values[0] - 0.75).abs() < 1e-6);
        b.update(0, 0.0);
        assert!((b.values[0] - 0.375).abs() < 1e-6);
    }

    #[test]
    fn contexts_learn_independently() {
        let mut b = Bandit::new(0.05, 0.3, 9);
        for _ in 0..1500 {
            let (t, s) = b.choose_threshold(Context(0));
            b.update(s, if t < 0.4 { 1.0 } else { 0.0 }); // ctx0: low best
            let (t, s) = b.choose_threshold(Context(7));
            b.update(s, if t > 0.7 { 1.0 } else { 0.0 }); // ctx7: high best
        }
        let argmax = |ctx: usize| {
            let base = ctx * THRESHOLDS.len();
            (0..4)
                .max_by(|&a, &c| b.values[base + a].total_cmp(&b.values[base + c]))
                .unwrap()
        };
        assert_eq!(argmax(0), 0);
        assert_eq!(argmax(7), 3);
    }

    #[test]
    fn choose_arm_stays_in_range_and_learns() {
        let mut b = Bandit::new(0.1, 0.3, 11);
        let ctx = Context(2);
        for _ in 0..1500 {
            let (arm, slot) = b.choose_arm(ctx, 2);
            assert!(arm < 2);
            assert_eq!(slot, ctx.0 * THRESHOLDS.len() + arm);
            // Arm 0 pays off, arm 1 doesn't.
            b.update(slot, if arm == 0 { 1.0 } else { 0.0 });
        }
        let base = ctx.0 * THRESHOLDS.len();
        assert!(b.values[base] > b.values[base + 1]);
        assert!(b.pulls[base] > b.pulls[base + 1]);
    }

    #[test]
    fn nan_values_do_not_panic_the_argmax() {
        // Regression: pick() used partial_cmp(..).unwrap(), which panics
        // the first greedy step after any value goes NaN. A poisoned
        // external table (set_values is the PJRT path) must degrade to
        // "some arm", deterministically, not abort the run.
        let mut b = Bandit::new(0.0, 0.1, 3); // ε = 0 → always greedy
        let mut poisoned = [f32::NAN; TOTAL_SLOTS];
        poisoned[2] = 0.25; // one finite value in context 0's table
        b.set_values(&poisoned);
        let (_, slot) = b.choose_threshold(Context(0));
        // total_cmp orders NaN above every finite f32, so the argmax
        // lands on a NaN arm — the point is that it lands at all.
        assert!(slot < THRESHOLDS.len());
        // All-NaN context: still no panic, and updates pull the chosen
        // slot back to a finite value eventually via v + lr·(r − v)
        // staying NaN — so also check a clean table recovers.
        let (_, slot7) = b.choose_threshold(Context(7));
        assert!((7 * THRESHOLDS.len()..8 * THRESHOLDS.len()).contains(&slot7));
        b.set_values(&[0.5; TOTAL_SLOTS]);
        let (t, _) = b.choose_threshold(Context(0));
        assert!(THRESHOLDS.contains(&t));
    }

    #[test]
    fn set_values_applies_external_table() {
        let mut b = Bandit::new(0.0, 0.1, 1);
        let ext = [0.9f32; TOTAL_SLOTS];
        b.set_values(&ext);
        assert!(b.values.iter().all(|&v| (v - 0.9).abs() < 1e-7));
    }
}
