//! The online ML controller (paper §IV): feature extraction, the logistic
//! scorer (native mirror of the Pallas kernel), the contextual bandit, and
//! the controller state machine tying them together.

pub mod bandit;
pub mod controller;
pub mod features;
pub mod logistic;
