//! The Online ML Controller (paper §IV): a logistic scorer gates each
//! prefetch candidate; a contextual bandit adapts the decision threshold
//! (and optionally the effective window size). Training runs periodically
//! at millisecond granularity on batched experience — through the AOT
//! PJRT artifact when available ([`Backend::Pjrt`]), or the bit-identical
//! native mirror otherwise.

use super::bandit::{Bandit, Context};
use super::features::{self, DecisionCtx, FeatureVec, DIM};
use super::logistic::Weights;
use crate::config::ControllerCfg;
use crate::obs::telemetry::{Telemetry, TelemetryMode};
use crate::prefetch::{Candidate, Outcome};
use crate::runtime::PjrtEngine;
use std::collections::HashMap;

/// Where training (and batch scoring) executes.
pub enum Backend {
    /// Rust mirror (identical math; used in the simulator hot path and
    /// when artifacts are absent).
    Native,
    /// AOT JAX/Pallas modules via the PJRT CPU client.
    Pjrt(PjrtEngine),
}

struct Pending {
    x: FeatureVec,
    thr_slot: usize,
    win_slot: Option<usize>,
}

/// Rolling decision statistics the engine reads for reporting.
#[derive(Clone, Copy, Debug, Default)]
pub struct ControllerStats {
    pub decisions: u64,
    pub issued: u64,
    pub skipped: u64,
    pub budget_denials: u64,
    pub trains: u64,
    pub last_loss: f32,
}

pub struct OnlineController {
    pub weights: Weights,
    bandit: Bandit,
    cfg: ControllerCfg,
    /// Decision-time context, maintained from outcome feedback + engine
    /// signals (bandwidth headroom, issue rate, churn, RPC tag).
    pub ctx: DecisionCtx,
    pending: HashMap<u64, Pending>,
    batch_x: Vec<f32>,
    batch_y: Vec<f32>,
    last_train: u64,
    // Token-bucket issue budget (the playbook's single knob, §VI-A).
    tokens: f64,
    last_refill: u64,
    backend: Backend,
    pub stats: ControllerStats,
}

/// Experience ring capacity (samples).
const MAX_EXPERIENCE: usize = 4096;
/// Minimum labeled samples before a training step fires.
const MIN_TRAIN_SAMPLES: usize = 64;
/// AOT batch size (must match python BATCH).
const AOT_BATCH: usize = 256;

impl OnlineController {
    pub fn new(cfg: ControllerCfg, seed: u64) -> Self {
        Self::with_backend(cfg, seed, Backend::Native)
    }

    pub fn with_backend(cfg: ControllerCfg, seed: u64, backend: Backend) -> Self {
        OnlineController {
            weights: Weights::default(),
            bandit: Bandit::new(cfg.epsilon, 0.1, seed ^ 0xBAD17),
            tokens: cfg.issue_budget_per_kcycle as f64,
            cfg,
            ctx: DecisionCtx {
                hit_ewma: 0.5,
                accuracy_ewma: 0.5,
                bw_headroom: 1.0,
                ..Default::default()
            },
            pending: HashMap::new(),
            batch_x: Vec::new(),
            batch_y: Vec::new(),
            last_train: 0,
            last_refill: 0,
            backend,
            stats: ControllerStats::default(),
        }
    }

    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Native => "native",
            Backend::Pjrt(_) => "pjrt",
        }
    }

    fn budget_ok(&mut self, cycle: u64) -> bool {
        let cap = self.cfg.issue_budget_per_kcycle;
        if cap == 0 {
            return true;
        }
        let elapsed = cycle.saturating_sub(self.last_refill);
        self.tokens = (self.tokens + elapsed as f64 * cap as f64 / 1000.0).min(cap as f64);
        self.last_refill = cycle;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Gate one candidate. Returns true to issue.
    pub fn decide(&mut self, cand: &Candidate, cycle: u64) -> bool {
        self.decide_t(cand, cycle, None)
    }

    /// [`Self::decide`] with a telemetry source (DESIGN.md §12). `None`
    /// is the exact path, bit-identical to pre-sketch builds. In sketch
    /// mode the decision context's per-context EWMAs are replaced by
    /// sketch estimates before scoring (and the sketch-fed feature
    /// vector is what lands in the experience buffer). In compare mode
    /// exact features drive the real decision while a sketch-fed shadow
    /// score is tallied against the same bandit threshold — zero extra
    /// RNG draws, so the run itself is unperturbed.
    pub fn decide_t(
        &mut self,
        cand: &Candidate,
        cycle: u64,
        telemetry: Option<&mut Telemetry>,
    ) -> bool {
        self.stats.decisions += 1;
        if !self.cfg.enabled {
            self.stats.issued += 1;
            return true;
        }
        let bctx = Context::from_signals(
            cand.window_density > 0.5,
            self.ctx.bw_headroom > 0.5,
            cand.short_loop,
        );
        // Optional window-size arm: truncate the candidate stream to the
        // chosen effective window.
        let mut win_slot = None;
        if self.cfg.adapt_window {
            let (win, slot) = self.bandit.choose_window(bctx);
            win_slot = Some(slot);
            if cand.offset >= win {
                self.stats.skipped += 1;
                return false;
            }
        }
        let exact_x = features::extract(cand, &self.ctx);
        let (x, shadow) = match telemetry {
            Some(t) => {
                let est = t.estimates(cand.src);
                let sx = features::extract(cand, &features::sketch_ctx(&self.ctx, &est));
                match t.cfg.mode {
                    TelemetryMode::Sketch => (sx, None),
                    TelemetryMode::Compare => (exact_x, Some((t, sx))),
                }
            }
            None => (exact_x, None),
        };
        let p = self.weights.score(&x);
        let (thr, thr_slot) = self.bandit.choose_threshold(bctx);
        if let Some((t, sx)) = shadow {
            let sp = self.weights.score(&sx);
            // Tally before the gate so every scored decision counts, on
            // only the substituted feature values (5..=7).
            t.tally_shadow(
                (p < thr) == (sp < thr),
                &[x[5], x[6], x[7]],
                &[sx[5], sx[6], sx[7]],
            );
        }
        if p < thr {
            self.stats.skipped += 1;
            return false;
        }
        if !self.budget_ok(cycle) {
            self.stats.budget_denials += 1;
            return false;
        }
        self.stats.issued += 1;
        self.pending.insert(
            cand.line,
            Pending {
                x,
                thr_slot,
                win_slot,
            },
        );
        true
    }

    /// Outcome feedback for an issued prefetch (reward shaping, §IV-B:
    /// "future hits minus penalties for evictions and useless fills").
    pub fn on_outcome(&mut self, line: u64, outcome: Outcome, caused_pollution: bool) {
        let (label, mut reward) = match outcome {
            Outcome::Timely => (1.0f32, 1.0f32),
            Outcome::Late => (1.0, 0.25),
            Outcome::Useless => (0.0, -0.5),
        };
        if caused_pollution {
            reward -= 1.0;
        }
        // EWMAs feeding the feature vector.
        let a = 0.02f32;
        let useful = matches!(outcome, Outcome::Timely | Outcome::Late);
        self.ctx.hit_ewma += a * (useful as u8 as f32 - self.ctx.hit_ewma);
        self.ctx.accuracy_ewma += a * (useful as u8 as f32 - self.ctx.accuracy_ewma);
        self.ctx.pollution_ewma += a * (caused_pollution as u8 as f32 - self.ctx.pollution_ewma);
        if let Some(p) = self.pending.remove(&line) {
            self.bandit.update(p.thr_slot, reward);
            if let Some(ws) = p.win_slot {
                self.bandit.update(ws, reward);
            }
            if self.batch_x.len() / DIM >= MAX_EXPERIENCE {
                // Drop the oldest half (ring semantics without a deque).
                let keep = MAX_EXPERIENCE / 2 * DIM;
                let cut = self.batch_x.len() - keep;
                self.batch_x.drain(..cut);
                self.batch_y.drain(..self.batch_y.len() - MAX_EXPERIENCE / 2);
            }
            self.batch_x.extend_from_slice(&p.x);
            self.batch_y.push(label);
        }
    }

    /// Engine-side signal refresh (bandwidth headroom, issue rate, churn,
    /// current RPC tag).
    pub fn set_signals(&mut self, bw_headroom: f32, issue_rate: f32, churn: f32, rpc_tag: u8) {
        self.ctx.bw_headroom = bw_headroom;
        self.ctx.issue_rate = issue_rate;
        self.ctx.churn = churn;
        self.ctx.rpc_tag = rpc_tag;
    }

    /// Periodic training step ("millisecond granularity", §IV-A). Returns
    /// the pre-step loss when a step ran.
    pub fn maybe_train(&mut self, cycle: u64) -> Option<f32> {
        if cycle.saturating_sub(self.last_train) < self.cfg.train_interval_cycles {
            return None;
        }
        self.last_train = cycle;
        let n = self.batch_y.len();
        if n < MIN_TRAIN_SAMPLES {
            return None;
        }
        let loss = match &mut self.backend {
            Backend::Native => {
                self.weights
                    .train_step(&self.batch_x, &self.batch_y, self.cfg.lr)
            }
            Backend::Pjrt(engine) => {
                // Fixed AOT batch: most recent 256 samples, resampled with
                // replacement when fewer are available.
                let mut xs = Vec::with_capacity(AOT_BATCH * DIM);
                let mut ys = Vec::with_capacity(AOT_BATCH);
                for i in 0..AOT_BATCH {
                    let idx = if n >= AOT_BATCH { n - AOT_BATCH + i } else { i % n };
                    xs.extend_from_slice(&self.batch_x[idx * DIM..(idx + 1) * DIM]);
                    ys.push(self.batch_y[idx]);
                }
                match engine.train_step(&self.weights.w, self.weights.b, &xs, &ys, self.cfg.lr) {
                    Ok((w, b, loss)) => {
                        self.weights.w = w;
                        self.weights.b = b;
                        loss
                    }
                    Err(e) => {
                        // Freeze parameters on failure (playbook: "freezing
                        // parameters during incidents").
                        crate::obs_warn!("controller: pjrt train failed, freezing: {e:#}");
                        return None;
                    }
                }
            }
        };
        self.stats.trains += 1;
        self.stats.last_loss = loss;
        Some(loss)
    }

    /// Drop experience and pending state (phase boundary / deployment
    /// rollback).
    pub fn reset_experience(&mut self) {
        self.batch_x.clear();
        self.batch_y.clear();
        self.pending.clear();
    }

    pub fn experience_len(&self) -> usize {
        self.batch_y.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(conf: u8, density: f32) -> Candidate {
        Candidate {
            line: 0x2000,
            src: 0x1000,
            conf,
            offset: 1,
            window_density: density,
            short_loop: false,
        }
    }

    fn cfg() -> ControllerCfg {
        ControllerCfg {
            train_interval_cycles: 1000,
            ..Default::default()
        }
    }

    #[test]
    fn issues_confident_skips_weak() {
        let mut c = OnlineController::new(cfg(), 1);
        // Make the bandit deterministic-greedy.
        c.bandit.epsilon = 0.0;
        let hi = c.decide(&cand(3, 0.9), 100);
        assert!(hi, "high-confidence dense candidate must issue");
        // Push pollution high, drop headroom, and remove the optimistic
        // bias → the weak candidate scores below every threshold arm.
        c.ctx.pollution_ewma = 1.0;
        c.ctx.bw_headroom = 0.0;
        c.weights.b = -1.0;
        let lo = c.decide(&cand(0, 0.0), 200);
        assert!(!lo, "weak candidate under pollution must be skipped");
        assert_eq!(c.stats.decisions, 2);
        assert_eq!(c.stats.issued, 1);
        assert_eq!(c.stats.skipped, 1);
    }

    #[test]
    fn disabled_controller_always_issues() {
        let mut c = OnlineController::new(
            ControllerCfg {
                enabled: false,
                ..cfg()
            },
            1,
        );
        for _ in 0..10 {
            assert!(c.decide(&cand(0, 0.0), 1));
        }
    }

    #[test]
    fn budget_cap_denies_when_exhausted() {
        let mut c = OnlineController::new(
            ControllerCfg {
                issue_budget_per_kcycle: 2,
                ..cfg()
            },
            1,
        );
        c.bandit.epsilon = 0.0;
        let mut issued = 0;
        for i in 0..10 {
            if c.decide(&cand(3, 0.9), 100 + i) {
                issued += 1;
            }
        }
        assert!(issued <= 2, "budget 2/kcycle, ~0 cycles elapsed: {issued}");
        assert!(c.stats.budget_denials >= 8);
        // Tokens refill with time.
        assert!(c.decide(&cand(3, 0.9), 5_000));
    }

    #[test]
    fn outcome_labels_and_trains_native() {
        let mut c = OnlineController::new(cfg(), 2);
        c.bandit.epsilon = 0.0;
        // Generate decisions + outcomes: dense/confident → timely,
        // sparse/weak → useless.
        let mut cycle = 0;
        while c.experience_len() < 200 {
            cycle += 10;
            let good = cand(3, 1.0);
            if c.decide(&good, cycle) {
                c.on_outcome(good.line, Outcome::Timely, false);
            }
            let bad = Candidate {
                line: 0x3000,
                ..cand(1, 0.125)
            };
            if c.decide(&bad, cycle) {
                c.on_outcome(bad.line, Outcome::Useless, true);
            }
            if cycle > 1_000_000 {
                break;
            }
        }
        assert!(c.experience_len() >= MIN_TRAIN_SAMPLES);
        let loss = c.maybe_train(cycle + 10_000);
        assert!(loss.is_some());
        assert_eq!(c.stats.trains, 1);
        // Second call inside the interval: no train.
        assert!(c.maybe_train(cycle + 10_001).is_none());
    }

    #[test]
    fn ewmas_track_outcomes() {
        let mut c = OnlineController::new(cfg(), 3);
        let h0 = c.ctx.hit_ewma;
        for _ in 0..100 {
            c.on_outcome(0x999, Outcome::Timely, false);
        }
        assert!(c.ctx.hit_ewma > h0);
        let p0 = c.ctx.pollution_ewma;
        for _ in 0..100 {
            c.on_outcome(0x999, Outcome::Useless, true);
        }
        assert!(c.ctx.pollution_ewma > p0);
    }

    #[test]
    fn experience_ring_is_bounded() {
        let mut c = OnlineController::new(cfg(), 4);
        c.bandit.epsilon = 0.0;
        for i in 0..(MAX_EXPERIENCE * 2) {
            let cd = Candidate {
                line: 0x4000 + i as u64,
                ..cand(3, 1.0)
            };
            if c.decide(&cd, i as u64 * 3) {
                c.on_outcome(cd.line, Outcome::Timely, false);
            }
        }
        assert!(c.experience_len() <= MAX_EXPERIENCE);
        assert_eq!(c.batch_x.len(), c.batch_y.len() * DIM);
    }

    #[test]
    fn compare_mode_never_perturbs_decisions() {
        // Same seed, same candidate stream: a compare-mode controller
        // must make decision-for-decision identical choices to a
        // telemetry-free twin (the shadow score costs no RNG draws).
        let mut exact = OnlineController::new(cfg(), 7);
        let mut shadowed = OnlineController::new(cfg(), 7);
        let mut t = Telemetry::from_knob("compare").unwrap().unwrap();
        let mut cycle = 0u64;
        let mut gated = 0u64;
        for i in 0..300u64 {
            cycle += 17;
            let cd = Candidate { line: 0x2000 + i, src: 0x1000 + i % 5, ..cand(3, 0.9) };
            let de = exact.decide(&cd, cycle);
            let ds = shadowed.decide_t(&cd, cycle, Some(&mut t));
            assert_eq!(de, ds, "decision {i} diverged");
            gated += 1;
            if ds {
                t.record_issue(cd.src);
                let useful = i % 4 != 0;
                let oc = if useful { Outcome::Timely } else { Outcome::Useless };
                exact.on_outcome(cd.line, oc, false);
                shadowed.on_outcome(cd.line, oc, false);
                t.record_outcome(cd.src, useful);
            }
        }
        assert_eq!(t.decisions_compared, gated);
        let agree = t.agreement().unwrap();
        assert!((0.0..=1.0).contains(&agree));
        assert!(t.feature_mae().is_some());
        assert_eq!(exact.stats.issued, shadowed.stats.issued);
        assert_eq!(exact.stats.skipped, shadowed.stats.skipped);
    }

    #[test]
    fn cold_sketch_mode_matches_exact_decisions() {
        // With no recorded outcomes the sketch estimates equal the exact
        // EWMAs' initial values (0.5 / 0.0 priors), so a sketch-mode
        // controller tracks a same-seed exact one exactly.
        let mut exact = OnlineController::new(cfg(), 9);
        let mut sketched = OnlineController::new(cfg(), 9);
        let mut t = Telemetry::from_knob("sketch").unwrap().unwrap();
        for i in 0..100u64 {
            let cd = Candidate {
                line: 0x2000 + i,
                src: 0x1000 + i % 3,
                ..cand((i % 4) as u8, (i % 8) as f32 / 8.0)
            };
            let de = exact.decide(&cd, 10 * i);
            let ds = sketched.decide_t(&cd, 10 * i, Some(&mut t));
            assert_eq!(de, ds, "cold decision {i} diverged");
        }
    }

    #[test]
    fn training_improves_discrimination() {
        // After enough labeled experience, the scorer should separate the
        // good candidate pattern from the bad one more than it did at init.
        let mut c = OnlineController::new(
            ControllerCfg {
                threshold: 0.0,
                train_interval_cycles: 500,
                lr: 0.3,
                epsilon: 0.0,
                ..cfg()
            },
            5,
        );
        c.bandit.epsilon = 0.0;
        let good = cand(3, 1.0);
        let bad = Candidate { line: 0x3000, conf: 1, window_density: 0.125, ..good };
        let gx = features::extract(&good, &c.ctx);
        let bx = features::extract(&bad, &c.ctx);
        let sep0 = c.weights.score(&gx) - c.weights.score(&bx);
        let mut cycle = 0u64;
        for _ in 0..40 {
            for _ in 0..64 {
                cycle += 100;
                if c.decide(&good, cycle) {
                    c.on_outcome(good.line, Outcome::Timely, false);
                }
                if c.decide(&bad, cycle) {
                    c.on_outcome(bad.line, Outcome::Useless, true);
                }
            }
            c.maybe_train(cycle + 1000);
            cycle += 1000;
        }
        // Score the *same* feature vectors used at sep0 for a fair compare.
        let sep1 = c.weights.score(&gx) - c.weights.score(&bx);
        // Labels dry up for the bad pattern once the scorer learns to skip
        // it (bandit feedback loop), so the gain is modest but must be
        // clearly positive.
        assert!(
            sep1 > sep0 + 0.02,
            "training did not improve separation: {sep0} -> {sep1}"
        );
    }
}
