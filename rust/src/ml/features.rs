//! Feature extraction for the online controller (paper §IV-A): "compact,
//! stable features: 20-bit PC delta pattern summary, window density,
//! recent hit and pollution counters, short loop indicator, and a
//! lightweight thread/RPC tag" — plus the operational signals (bandwidth
//! headroom, issue rate, churn) the deployment playbook keys on.

use crate::obs::telemetry::CtxEstimates;
use crate::prefetch::Candidate;

/// Feature dimensionality — must match `python/compile/kernels/logistic.py
/// FEATURES` (checked against the AOT manifest at runtime load).
pub const DIM: usize = 16;

/// Engine-side context sampled at decision time.
#[derive(Clone, Copy, Debug, Default)]
pub struct DecisionCtx {
    /// EWMA of recent prefetch hit (useful) rate.
    pub hit_ewma: f32,
    /// EWMA of recent pollution rate.
    pub pollution_ewma: f32,
    /// EWMA of recent accuracy.
    pub accuracy_ewma: f32,
    /// DRAM bandwidth headroom in [0,1].
    pub bw_headroom: f32,
    /// Prefetches issued per kilocycle (normalized /32).
    pub issue_rate: f32,
    /// Phase-churn indicator: relative miss-rate delta vs previous window.
    pub churn: f32,
    /// RPC/handler tag of the triggering fetch.
    pub rpc_tag: u8,
}

/// A fixed-size feature vector.
pub type FeatureVec = [f32; DIM];

/// Build the scorer input for one candidate.
pub fn extract(cand: &Candidate, ctx: &DecisionCtx) -> FeatureVec {
    let mut f = [0.0f32; DIM];
    f[0] = 1.0; // bias
    f[1] = cand.conf as f32 / 3.0;
    f[2] = cand.window_density;
    f[3] = cand.offset as f32 / 12.0;
    f[4] = if cand.short_loop { 1.0 } else { 0.0 };
    f[5] = ctx.hit_ewma;
    f[6] = ctx.pollution_ewma;
    f[7] = ctx.accuracy_ewma;
    f[8] = ctx.bw_headroom;
    f[9] = (ctx.issue_rate / 32.0).min(1.0);
    // 20-bit PC delta pattern summary: popcount of the low-order XOR —
    // distinguishes near-sequential deltas (low popcount) from scattered
    // ones without storing addresses (privacy note, §VII).
    let delta_pattern = ((cand.src ^ cand.line) & 0xF_FFFF).count_ones();
    f[10] = delta_pattern as f32 / 20.0;
    // RPC tag one-hot (4 buckets).
    f[11 + (ctx.rpc_tag as usize % 4)] = 1.0;
    f[15] = ctx.churn.clamp(0.0, 1.0);
    f
}

/// Sketch-backed variant of the decision context (DESIGN.md §12):
/// splice bounded-memory sketch estimates over the three exact
/// per-context EWMAs, keeping every signal-driven field (headroom,
/// issue rate, churn, tag) from the engine as-is. Under the
/// `telemetry: "sketch"` knob [`extract`] runs on this context instead
/// of the exact one — same feature layout, compressed source.
pub fn sketch_ctx(base: &DecisionCtx, est: &CtxEstimates) -> DecisionCtx {
    DecisionCtx {
        hit_ewma: est.hit,
        pollution_ewma: est.pollution,
        accuracy_ewma: est.accuracy,
        ..*base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand() -> Candidate {
        Candidate {
            line: 0x1005,
            src: 0x1000,
            conf: 3,
            offset: 5,
            window_density: 0.5,
            short_loop: true,
        }
    }

    #[test]
    fn bias_and_ranges() {
        let ctx = DecisionCtx {
            hit_ewma: 0.7,
            pollution_ewma: 0.1,
            accuracy_ewma: 0.8,
            bw_headroom: 0.9,
            issue_rate: 16.0,
            churn: 2.0, // clamped
            rpc_tag: 2,
        };
        let f = extract(&cand(), &ctx);
        assert_eq!(f[0], 1.0);
        assert_eq!(f[1], 1.0);
        assert_eq!(f[4], 1.0);
        assert_eq!(f[9], 0.5);
        assert_eq!(f[13], 1.0); // tag 2 one-hot
        assert_eq!(f[15], 1.0); // clamped churn
        for v in f {
            assert!((0.0..=1.0).contains(&v), "feature out of range: {v}");
        }
    }

    #[test]
    fn delta_pattern_reflects_distance() {
        let near = extract(
            &Candidate { line: 0x1001, ..cand() },
            &DecisionCtx::default(),
        );
        let far = extract(
            &Candidate { line: 0x1000 ^ 0xF_F0F0, ..cand() },
            &DecisionCtx::default(),
        );
        assert!(near[10] < far[10]);
    }

    #[test]
    fn sketch_ctx_substitutes_only_the_tracked_ewmas() {
        let base = DecisionCtx {
            hit_ewma: 0.7,
            pollution_ewma: 0.1,
            accuracy_ewma: 0.8,
            bw_headroom: 0.9,
            issue_rate: 16.0,
            churn: 0.25,
            rpc_tag: 2,
        };
        let est = CtxEstimates { hit: 0.6, pollution: 0.2, accuracy: 0.6 };
        let s = sketch_ctx(&base, &est);
        assert_eq!(s.hit_ewma, 0.6);
        assert_eq!(s.pollution_ewma, 0.2);
        assert_eq!(s.accuracy_ewma, 0.6);
        // Signal-driven fields pass through untouched.
        assert_eq!(s.bw_headroom, base.bw_headroom);
        assert_eq!(s.issue_rate, base.issue_rate);
        assert_eq!(s.churn, base.churn);
        assert_eq!(s.rpc_tag, base.rpc_tag);
        // The extracted vectors differ exactly on features 5..=7.
        let fe = extract(&cand(), &base);
        let fs = extract(&cand(), &s);
        for i in 0..DIM {
            if (5..=7).contains(&i) {
                assert_ne!(fe[i], fs[i], "feature {i}");
            } else {
                assert_eq!(fe[i], fs[i], "feature {i}");
            }
        }
    }

    #[test]
    fn rpc_tags_are_distinct() {
        for t in 0..4u8 {
            let f = extract(&cand(), &DecisionCtx { rpc_tag: t, ..Default::default() });
            assert_eq!(f[11 + t as usize], 1.0);
            let hot: usize = (11..15).filter(|&i| f[i] > 0.0).count();
            assert_eq!(hot, 1);
        }
    }
}
