//! Native logistic scorer — the Rust mirror of the Pallas kernel
//! (`python/compile/kernels/logistic.py`).
//!
//! The per-decision inner loop uses this fixed-path implementation (a
//! hardware controller would be a small MAC array); the AOT/PJRT artifact
//! executes the *identical math* for periodic training and batch
//! calibration. Integration tests assert parity ≤ 1e-5 between the two
//! (`rust/tests/integration_runtime.rs`).

use super::features::{FeatureVec, DIM};

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Weights {
    pub w: [f32; DIM],
    pub b: f32,
}

impl Default for Weights {
    fn default() -> Self {
        // Mildly optimistic prior: confidence and density vote for issue,
        // pollution votes against — converges fast either way; chosen so
        // an untrained controller behaves like a sane static filter.
        let mut w = [0.0f32; DIM];
        w[1] = 1.0; // confidence
        w[2] = 0.8; // window density
        w[6] = -1.0; // pollution EWMA
        w[8] = 0.5; // bandwidth headroom
        Weights { w, b: 0.2 }
    }
}

#[inline]
pub fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

impl Weights {
    /// Score one candidate: the calibrated issue probability.
    #[inline]
    pub fn score(&self, x: &FeatureVec) -> f32 {
        let mut z = self.b;
        for i in 0..DIM {
            z += self.w[i] * x[i];
        }
        sigmoid(z)
    }

    /// Score a batch laid out row-major `[n, DIM]` (mirrors the Pallas
    /// kernel's batched GEMV; used for parity tests and shadow scoring).
    pub fn score_batch(&self, xs: &[f32]) -> Vec<f32> {
        assert_eq!(xs.len() % DIM, 0);
        xs.chunks_exact(DIM)
            .map(|row| {
                let mut z = self.b;
                for i in 0..DIM {
                    z += self.w[i] * row[i];
                }
                sigmoid(z)
            })
            .collect()
    }

    /// One BCE-SGD step — the same analytic gradient as the Pallas
    /// `_grad_kernel` (g = p - y; dw = xᵀg/B; db = mean g). Returns the
    /// pre-step mean BCE loss. Native fallback when no PJRT artifacts are
    /// present; bit-compared against the AOT path in integration tests.
    pub fn train_step(&mut self, xs: &[f32], ys: &[f32], lr: f32) -> f32 {
        assert_eq!(xs.len(), ys.len() * DIM);
        let n = ys.len();
        if n == 0 {
            return 0.0;
        }
        let inv_n = 1.0 / n as f32;
        let mut dw = [0.0f32; DIM];
        let mut db = 0.0f32;
        let mut loss = 0.0f32;
        for (row, &y) in xs.chunks_exact(DIM).zip(ys) {
            let p = {
                let mut z = self.b;
                for i in 0..DIM {
                    z += self.w[i] * row[i];
                }
                sigmoid(z)
            };
            let g = p - y;
            for i in 0..DIM {
                dw[i] += g * row[i];
            }
            db += g;
            let pc = p.clamp(1e-7, 1.0 - 1e-7);
            loss -= y * pc.ln() + (1.0 - y) * (1.0 - pc).ln();
        }
        for i in 0..DIM {
            self.w[i] -= lr * dw[i] * inv_n;
        }
        self.b -= lr * db * inv_n;
        loss * inv_n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sigmoid_basics() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(20.0) > 0.999);
        assert!(sigmoid(-20.0) < 0.001);
    }

    #[test]
    fn score_batch_matches_single() {
        let wts = Weights::default();
        let mut r = Rng::new(3);
        let mut xs = Vec::new();
        let mut singles = Vec::new();
        for _ in 0..10 {
            let mut f = [0.0f32; DIM];
            for v in f.iter_mut() {
                *v = r.f32();
            }
            singles.push(wts.score(&f));
            xs.extend_from_slice(&f);
        }
        let batch = wts.score_batch(&xs);
        for (a, b) in batch.iter().zip(&singles) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn training_learns_separable_rule() {
        // Same scenario as python/tests/test_kernel.py
        // ::test_training_reduces_loss_on_separable_data.
        let mut r = Rng::new(7);
        let mut true_w = [0.0f32; DIM];
        for v in true_w.iter_mut() {
            *v = r.f32() * 2.0 - 1.0;
        }
        let n = 256;
        let mut xs = Vec::with_capacity(n * DIM);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let mut dot = 0.0f32;
            let mut row = [0.0f32; DIM];
            for i in 0..DIM {
                row[i] = r.f32() * 2.0 - 1.0;
                dot += row[i] * true_w[i];
            }
            xs.extend_from_slice(&row);
            ys.push(if dot > 0.0 { 1.0 } else { 0.0 });
        }
        let mut wts = Weights {
            w: [0.0; DIM],
            b: 0.0,
        };
        let first = wts.train_step(&xs, &ys, 0.5);
        let mut last = first;
        for _ in 0..80 {
            last = wts.train_step(&xs, &ys, 0.5);
        }
        assert!(
            last < 0.4 * first,
            "loss did not drop: {first} -> {last}"
        );
    }

    #[test]
    fn empty_batch_is_noop() {
        let mut wts = Weights::default();
        let before = wts;
        assert_eq!(wts.train_step(&[], &[], 0.1), 0.0);
        assert_eq!(wts, before);
    }

    #[test]
    fn default_prior_prefers_confident_dense() {
        let wts = Weights::default();
        let mut hi = [0.0f32; DIM];
        hi[0] = 1.0;
        hi[1] = 1.0;
        hi[2] = 1.0;
        hi[8] = 1.0;
        let mut lo = [0.0f32; DIM];
        lo[0] = 1.0;
        lo[6] = 1.0; // pure pollution signal
        assert!(wts.score(&hi) > 0.7);
        assert!(wts.score(&lo) < 0.4);
    }
}
