//! The trace-driven simulation engine: decoupled-frontend timing over the
//! Table-I hierarchy with prefetching, timeliness, pollution, bandwidth,
//! and the online ML controller in the loop.
//!
//! Timing model (DESIGN.md "Simulator timing model"): retiring cycles are
//! `instrs × base_cpi`; an uncovered L1-I miss stalls the frontend for the
//! serving level's latency (plus DRAM queueing); late prefetches expose
//! their residual; bad speculation is a per-instruction expectation. This
//! reproduces the *relative* speedup/MPKI/accuracy structure the paper
//! reports without a full OoO pipeline (the paper's own threats-to-
//! validity note applies the same caveat to ZSim, §X-D).

use super::bandwidth::DramModel;
use super::cache::Cache;
use super::inflight::{Inflight, InflightEntry, PrefetchMatch};
use super::stats::SimStats;
use crate::config::{PrefetcherKind, SimConfig};
use crate::ml::controller::OnlineController;
use crate::obs::telemetry::Telemetry;
use crate::prefetch::{self, Candidate, Feedback, Outcome, PairStats, Prefetcher};
use crate::trace::{Kind, Record};
use crate::util::hashfx::FxHashMap;
use std::collections::VecDeque;

/// Pollution attribution horizon: a demand miss on a line evicted by a
/// prefetch within this many cycles counts as a harmful eviction.
const POLLUTION_HORIZON: u64 = 50_000;
/// Victim-tracking capacity (recent L1-I evictions).
const VICTIM_CAP: usize = 4096;
/// Oracle lookahead depth (records) for the perfect prefetcher.
const PERFECT_LOOKAHEAD: usize = 64;
/// Controller signal refresh period (records).
const SIGNAL_PERIOD: u64 = 256;

/// Everything a run produces.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub app: String,
    pub label: String,
    pub stats: SimStats,
    pub pair_stats: PairStats,
    pub metadata_bytes: u64,
    pub controller: Option<crate::ml::controller::ControllerStats>,
    /// Per-request cycle counts, one per maximal run of records sharing
    /// a `ctx` tag (`Some` only under `SimConfig::track_segments`) —
    /// the raw material for empirical service-time distributions
    /// (DESIGN.md §8).
    pub segments: Option<Vec<f64>>,
    /// Sketch telemetry summaries (`Some` only when `SimConfig::telemetry`
    /// is not `"exact"`) — per-context prefetch counters, cardinality,
    /// and heavy hitters, plus compare-mode accuracy tallies
    /// (DESIGN.md §12).
    pub telemetry: Option<Box<Telemetry>>,
}

impl SimResult {
    pub fn ipc(&self) -> f64 {
        self.stats.ipc()
    }
}

pub struct Engine<'t> {
    cfg: SimConfig,
    records: &'t [Record],
    pos: usize,
    /// Integer cycle counter plus a fractional accumulator.
    cycle: u64,
    frac_acc: f64,
    l1i: Cache,
    l1d: Cache,
    l2: Cache,
    l3: Cache,
    dram: DramModel,
    inflight: Inflight,
    pf: Box<dyn Prefetcher>,
    pub controller: Option<OnlineController>,
    stats: SimStats,
    /// Recent L1-I evictions: line → (evict cycle, evicted-by-prefetch).
    victims: FxHashMap<u64, (u64, bool)>,
    victim_fifo: VecDeque<u64>,
    cand_buf: Vec<Candidate>,
    nl_last: u64,
    perfect: bool,
    /// §VI-A shadow mode: decide + log, never fill.
    shadow: bool,
    /// Cooldown marker for the §VII anomaly guardrail.
    last_anomaly_window: u64,
    signal_windows: u64,
    // Controller signal bookkeeping.
    issued_recent: u32,
    signal_mark: u64,
    misses_this_window: u64,
    misses_prev_window: u64,
    // Per-ctx-segment cycle tracking (observation only, off by default).
    seg_prev_ctx: Option<u8>,
    seg_mark: f64,
    segments: Vec<f64>,
    /// Sketch telemetry (None = exact mode, the baseline path).
    telemetry: Option<Box<Telemetry>>,
}

impl<'t> Engine<'t> {
    pub fn new(cfg: SimConfig, records: &'t [Record]) -> Self {
        let h = cfg.hierarchy;
        let perfect = matches!(cfg.prefetcher, PrefetcherKind::Perfect);
        let pf = prefetch::build(&cfg);
        let controller = cfg
            .controller
            .clone()
            .filter(|c| c.enabled)
            .map(|c| OnlineController::new(c, cfg.seed));
        // The knob is validated wherever configs are parsed (spec/CLI);
        // a hand-built SimConfig with a bad string fails loudly here.
        let telemetry = Telemetry::from_knob(&cfg.telemetry)
            .expect("validated telemetry knob")
            .map(Box::new);
        Engine {
            records,
            pos: 0,
            cycle: 0,
            frac_acc: 0.0,
            l1i: Cache::new(h.l1i),
            l1d: Cache::new(h.l1d),
            l2: Cache::new(h.l2),
            l3: Cache::new(h.l3),
            dram: DramModel::new(h.dram_latency, h.dram_bytes_per_cycle),
            inflight: Inflight::new(),
            pf,
            controller,
            stats: SimStats::default(),
            victims: FxHashMap::default(),
            victim_fifo: VecDeque::new(),
            cand_buf: Vec::with_capacity(16),
            nl_last: u64::MAX,
            perfect,
            shadow: cfg.controller.as_ref().map(|c| c.shadow).unwrap_or(false),
            last_anomaly_window: 0,
            signal_windows: 0,
            issued_recent: 0,
            signal_mark: 0,
            misses_this_window: 0,
            misses_prev_window: 0,
            seg_prev_ctx: None,
            seg_mark: 0.0,
            segments: Vec::new(),
            telemetry,
            cfg,
        }
    }

    /// Cycle counter including the fractional accumulator.
    #[inline]
    fn now_cycles(&self) -> f64 {
        self.cycle as f64 + self.frac_acc
    }

    /// Close the open `ctx` segment (if any) at the current cycle and
    /// start a new one.
    fn roll_segment(&mut self, ctx: u8) {
        let now = self.now_cycles();
        if self.seg_prev_ctx.is_some() {
            self.segments.push(now - self.seg_mark);
        }
        self.seg_mark = now;
        self.seg_prev_ctx = Some(ctx);
    }

    /// Attach a pre-built controller (e.g. with a PJRT backend).
    pub fn with_controller(mut self, c: OnlineController) -> Self {
        self.controller = Some(c);
        self
    }

    /// Advance fractional cycles (retire / bad-spec expectations).
    #[inline]
    fn advance_frac(&mut self, amount: f64) {
        self.frac_acc += amount;
        let whole = self.frac_acc as u64;
        self.cycle += whole;
        self.frac_acc -= whole as f64;
    }

    /// Serve a fill from L2 → L3 → DRAM; fills the touched levels.
    /// Returns the fill latency.
    fn serve_fill(&mut self, line: u64, is_demand: bool) -> u64 {
        if self.l2.access(line) {
            return self.cfg.hierarchy.l2.latency;
        }
        if self.l3.access(line) {
            self.l2.insert(line, !is_demand);
            return self.cfg.hierarchy.l3.latency;
        }
        let done = self
            .dram
            .transfer(self.cycle, self.cfg.hierarchy.l1i.line_b, is_demand);
        self.l3.insert(line, !is_demand);
        self.l2.insert(line, !is_demand);
        done - self.cycle
    }

    /// Record an L1-I eviction for pollution attribution + CHEIP hooks.
    fn note_eviction(&mut self, victim: super::cache::Evicted, by_prefetch: bool) {
        self.pf.on_l1i_evict(victim.line);
        if victim.was_prefetch_unused {
            self.stats.pf_useless += 1;
            if let Some(e) = self.inflight.evict(victim.line) {
                self.pf.feedback(&Feedback {
                    src: e.src,
                    line: victim.line,
                    outcome: Outcome::Useless,
                });
                if let Some(c) = &mut self.controller {
                    c.on_outcome(victim.line, Outcome::Useless, false);
                }
                if let Some(t) = &mut self.telemetry {
                    t.record_outcome(e.src, false);
                }
            }
        }
        if self.victim_fifo.len() >= VICTIM_CAP {
            if let Some(old) = self.victim_fifo.pop_front() {
                self.victims.remove(&old);
            }
        }
        self.victim_fifo.push_back(victim.line);
        self.victims.insert(victim.line, (self.cycle, by_prefetch));
    }

    /// Insert into L1-I, wiring eviction bookkeeping.
    fn l1i_fill(&mut self, line: u64, is_prefetch: bool) {
        if let Some(victim) = self.l1i.insert(line, is_prefetch) {
            self.note_eviction(victim, is_prefetch);
        }
        self.pf.on_l1i_fill(line, self.cycle);
    }

    /// Try to issue one prefetch (after dedup). Returns whether issued.
    fn issue_prefetch(&mut self, line: u64, src: u64) -> bool {
        if self.l1i.contains(line) || self.inflight.contains(line) {
            return false;
        }
        let latency = self.serve_fill(line, false);
        let entry = InflightEntry {
            ready_at: self.cycle + 1 + latency,
            src,
            decision: usize::MAX,
        };
        self.inflight.issue(line, entry);
        self.l1i_fill(line, true);
        self.stats.pf_issued += 1;
        self.issued_recent += 1;
        if let Some(t) = &mut self.telemetry {
            t.record_issue(src);
        }
        true
    }

    /// One instruction-fetch record.
    fn step_fetch(&mut self, rec: Record) {
        let line = rec.line;
        self.stats.instrs += rec.instrs as u64;
        self.stats.l1i_accesses += 1;
        // Retiring + bad-speculation cycle expectations.
        let retire = rec.instrs as f64 * self.cfg.base_cpi;
        let badspec =
            rec.instrs as f64 * self.cfg.mispredict_rate * self.cfg.mispredict_penalty;
        self.stats.topdown.retiring += retire;
        self.stats.topdown.bad_spec += badspec;
        self.advance_frac(retire + badspec);

        let access = self.l1i.access_rich(line);
        if access == super::cache::Access::Miss {
            self.misses_this_window += 1;
            let (m, entry) = self.inflight.demand(line, self.cycle);
            match m {
                PrefetchMatch::Timely => {
                    self.stats.pf_timely += 1;
                    let e = entry.unwrap();
                    self.pf.feedback(&Feedback {
                        src: e.src,
                        line,
                        outcome: Outcome::Timely,
                    });
                    if let Some(c) = &mut self.controller {
                        c.on_outcome(line, Outcome::Timely, false);
                    }
                    if let Some(t) = &mut self.telemetry {
                        t.record_outcome(e.src, true);
                    }
                    self.l1i_fill(line, false);
                }
                PrefetchMatch::Late { residual } => {
                    self.stats.pf_late += 1;
                    self.stats.topdown.frontend += residual as f64;
                    self.cycle += residual;
                    let e = entry.unwrap();
                    self.pf.feedback(&Feedback {
                        src: e.src,
                        line,
                        outcome: Outcome::Late,
                    });
                    if let Some(c) = &mut self.controller {
                        c.on_outcome(line, Outcome::Late, false);
                    }
                    if let Some(t) = &mut self.telemetry {
                        t.record_outcome(e.src, true);
                    }
                    self.l1i_fill(line, false);
                }
                PrefetchMatch::None => {
                    // Uncovered demand miss.
                    self.stats.l1i_demand_misses += 1;
                    if let Some(&(t, by_pf)) = self.victims.get(&line) {
                        if by_pf && self.cycle.saturating_sub(t) < POLLUTION_HORIZON {
                            self.stats.pollution_misses += 1;
                        }
                    }
                    self.pf.on_demand_miss(line, self.cycle);
                    let fetch_cycle = self.cycle;
                    let latency = self.serve_fill(line, true);
                    self.stats.topdown.frontend += latency as f64;
                    self.cycle += latency;
                    self.l1i_fill(line, false);
                    self.pf.on_miss_resolved(line, fetch_cycle, latency);
                }
            }
        } else if access == super::cache::Access::HitPrefetched {
            // First demand hit on a prefetch-resident line claims the
            // in-flight entry (no map probe on ordinary hits — §Perf).
            // Lines fill at issue time, so *this* is where timeliness
            // resolves: a still-in-flight prefetch exposes its residual.
            let (m, entry) = self.inflight.demand(line, self.cycle);
            match (m, entry) {
                (PrefetchMatch::Timely, Some(e)) => {
                    self.stats.pf_timely += 1;
                    self.pf.feedback(&Feedback {
                        src: e.src,
                        line,
                        outcome: Outcome::Timely,
                    });
                    if let Some(c) = &mut self.controller {
                        c.on_outcome(line, Outcome::Timely, false);
                    }
                    if let Some(t) = &mut self.telemetry {
                        t.record_outcome(e.src, true);
                    }
                }
                (PrefetchMatch::Late { residual }, Some(e)) => {
                    self.stats.pf_late += 1;
                    self.stats.topdown.frontend += residual as f64;
                    self.cycle += residual;
                    self.pf.feedback(&Feedback {
                        src: e.src,
                        line,
                        outcome: Outcome::Late,
                    });
                    if let Some(c) = &mut self.controller {
                        c.on_outcome(line, Outcome::Late, false);
                    }
                    if let Some(t) = &mut self.telemetry {
                        t.record_outcome(e.src, true);
                    }
                }
                _ => {}
            }
        }

        // Built-in next-line prefetcher (always on, §X-B).
        if line != self.nl_last {
            self.nl_last = line;
            self.issue_prefetch(line + 1, line);
        }

        // Main prefetcher candidates, gated by the controller.
        let mut cand_buf = std::mem::take(&mut self.cand_buf);
        cand_buf.clear();
        self.pf.on_fetch(line, self.cycle, &mut cand_buf);
        for cand in &cand_buf {
            let issue = match &mut self.controller {
                Some(c) => c.decide_t(cand, self.cycle, self.telemetry.as_deref_mut()),
                None => true,
            };
            if issue {
                if self.shadow {
                    // §VI-A shadow mode: log predicted utility +
                    // hypothetical bandwidth, issue nothing.
                    self.stats.shadow_would_issue += 1;
                    self.stats.shadow_bytes += self.cfg.hierarchy.l1i.line_b as u64;
                } else {
                    self.issue_prefetch(cand.line, cand.src);
                }
            } else {
                self.stats.pf_skipped += 1;
            }
        }
        self.cand_buf = cand_buf;

        // Oracle mode: prefetch the literal future.
        if self.perfect {
            let end = (self.pos + 1 + PERFECT_LOOKAHEAD).min(self.records.len());
            for i in self.pos + 1..end {
                let r = self.records[i];
                if r.kind == Kind::Fetch {
                    self.issue_prefetch(r.line, line);
                }
            }
        }
    }

    /// One data-access record (L1D with its NLP, Table I).
    fn step_data(&mut self, rec: Record) {
        self.stats.l1d_accesses += 1;
        if !self.l1d.access(rec.line) {
            self.stats.l1d_misses += 1;
            let latency = self.serve_fill(rec.line, true);
            let exposed = latency as f64 * self.cfg.backend_expose;
            self.stats.topdown.backend += exposed;
            self.advance_frac(exposed);
            self.l1d.insert(rec.line, false);
            // L1D next-line prefetch ("with NLP").
            if !self.l1d.contains(rec.line + 1) {
                self.serve_fill(rec.line + 1, false);
                self.l1d.insert(rec.line + 1, true);
            }
        }
    }

    fn refresh_signals(&mut self, ctx_tag: u8) {
        self.signal_windows += 1;
        let issued = self.issued_recent;
        self.issued_recent = 0;
        let churn = if self.misses_prev_window > 0 {
            let cur = self.misses_this_window as f64;
            let prev = self.misses_prev_window as f64;
            ((cur - prev).abs() / prev).min(1.0) as f32
        } else {
            0.0
        };
        // §VII guardrail: an anomalous miss burst (miss rate doubling
        // within a window) decays learned confidence, with a cooldown so
        // sustained churn doesn't permanently wipe the tables.
        if churn > 0.75
            && self.misses_this_window > 16
            && self.misses_prev_window >= 8
            && self.signal_windows - self.last_anomaly_window > 16
        {
            self.last_anomaly_window = self.signal_windows;
            self.stats.anomaly_resets += 1;
            self.pf.on_anomaly();
        }
        self.misses_prev_window = self.misses_this_window;
        self.misses_this_window = 0;
        let elapsed_kcycles =
            (self.cycle.saturating_sub(self.signal_mark)).max(1) as f32 / 1000.0;
        self.signal_mark = self.cycle;
        let headroom = self.dram.headroom(self.cycle, 1000.0) as f32;
        if let Some(c) = &mut self.controller {
            c.set_signals(headroom, issued as f32 / elapsed_kcycles, churn, ctx_tag);
            c.maybe_train(self.cycle);
        }
    }

    /// Run to completion.
    pub fn run(mut self) -> SimResult {
        let track = self.cfg.track_segments;
        for i in 0..self.records.len() {
            self.pos = i;
            let rec = self.records[i];
            if track && self.seg_prev_ctx != Some(rec.ctx) {
                self.roll_segment(rec.ctx);
            }
            match rec.kind {
                Kind::Fetch => self.step_fetch(rec),
                Kind::Load | Kind::Store => self.step_data(rec),
            }
            if i as u64 % SIGNAL_PERIOD == SIGNAL_PERIOD - 1 {
                self.refresh_signals(rec.ctx);
            }
        }
        if track && self.seg_prev_ctx.is_some() {
            let end = self.now_cycles();
            self.segments.push(end - self.seg_mark);
        }
        self.stats.cycles = self.cycle as f64 + self.frac_acc;
        self.stats.dram_bytes = self.dram.bytes_total;
        self.stats.dram_transfers = self.dram.transfers;
        SimResult {
            app: String::new(),
            label: self.cfg.prefetcher.label(),
            stats: self.stats,
            pair_stats: self.pf.pair_stats(),
            metadata_bytes: self.pf.metadata_bytes(),
            controller: self.controller.as_ref().map(|c| c.stats),
            segments: track.then_some(self.segments),
            telemetry: self.telemetry,
        }
    }
}

/// Convenience: run one config over records.
pub fn run(cfg: &SimConfig, records: &[Record]) -> SimResult {
    Engine::new(cfg.clone(), records).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ControllerCfg, PrefetcherKind, SimConfig};
    use crate::trace::gen::{apps, generate_records};

    fn trace(name: &str, n: u64) -> Vec<Record> {
        generate_records(&apps::app(name).unwrap(), 7, n)
    }

    fn run_kind(records: &[Record], kind: PrefetcherKind) -> SimResult {
        let cfg = SimConfig {
            prefetcher: kind,
            ..Default::default()
        };
        run(&cfg, records)
    }

    #[test]
    fn sequential_trace_nl_covers_everything() {
        let recs: Vec<Record> = (0..20_000u64).map(|i| Record::fetch(i, 16, 0)).collect();
        let r = run_kind(&recs, PrefetcherKind::NextLineOnly);
        assert!(
            r.stats.l1i_demand_misses < 20,
            "uncovered misses on a pure stream: {}",
            r.stats.l1i_demand_misses
        );
    }

    #[test]
    fn deterministic_runs() {
        let recs = trace("serde", 30_000);
        let a = run_kind(&recs, PrefetcherKind::Eip { entries: 256 });
        let b = run_kind(&recs, PrefetcherKind::Eip { entries: 256 });
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.stats.pf_issued, b.stats.pf_issued);
    }

    #[test]
    fn eip_beats_nl_baseline_on_microservice_trace() {
        let recs = trace("websearch", 200_000);
        let nl = run_kind(&recs, PrefetcherKind::NextLineOnly);
        let eip = run_kind(&recs, PrefetcherKind::Eip { entries: 256 });
        assert!(
            eip.ipc() > nl.ipc(),
            "EIP must beat NL: {} vs {}",
            eip.ipc(),
            nl.ipc()
        );
        assert!(eip.stats.mpki() < nl.stats.mpki());
    }

    #[test]
    fn perfect_is_upper_bound() {
        let recs = trace("admission", 150_000);
        let nl = run_kind(&recs, PrefetcherKind::NextLineOnly);
        let eip = run_kind(&recs, PrefetcherKind::Eip { entries: 256 });
        let perfect = run_kind(&recs, PrefetcherKind::Perfect);
        assert!(perfect.ipc() >= eip.ipc());
        assert!(perfect.ipc() > nl.ipc() * 1.01);
    }

    #[test]
    fn ceip_close_to_eip_with_less_metadata() {
        let recs = trace("websearch", 200_000);
        let nl = run_kind(&recs, PrefetcherKind::NextLineOnly);
        let eip = run_kind(&recs, PrefetcherKind::Eip { entries: 256 });
        let ceip = run_kind(
            &recs,
            PrefetcherKind::Ceip { entries: 256, window: 8, whole_window: true },
        );
        assert!(ceip.metadata_bytes < eip.metadata_bytes / 3);
        let eip_speedup = eip.ipc() / nl.ipc();
        let ceip_speedup = ceip.ipc() / nl.ipc();
        assert!(ceip_speedup > 1.0, "CEIP must beat the NL baseline");
        // Paper §X-C: CEIP within a few percent of EIP.
        assert!(
            ceip_speedup > eip_speedup * 0.90,
            "CEIP too far below EIP: {ceip_speedup} vs {eip_speedup}"
        );
    }

    #[test]
    fn prefetch_accounting_consistent() {
        let recs = trace("logging", 100_000);
        let r = run_kind(
            &recs,
            PrefetcherKind::Ceip { entries: 256, window: 8, whole_window: true },
        );
        let used = r.stats.pf_timely + r.stats.pf_late;
        assert!(used <= r.stats.pf_issued);
        assert!(r.stats.accuracy() <= 1.0);
        assert!(r.stats.coverage() <= 1.0);
        assert!(r.stats.pf_issued > 0);
    }

    #[test]
    fn controller_reduces_useless_prefetches() {
        let recs = trace("abscheduler-java", 200_000);
        let base_cfg = SimConfig {
            prefetcher: PrefetcherKind::Ceip { entries: 256, window: 8, whole_window: true },
            ..Default::default()
        };
        let no_ctrl = run(&base_cfg, &recs);
        let with_ctrl = run(
            &SimConfig {
                controller: Some(ControllerCfg {
                    train_interval_cycles: 100_000,
                    ..Default::default()
                }),
                ..base_cfg
            },
            &recs,
        );
        assert!(with_ctrl.stats.pf_skipped > 0, "controller never skipped");
        assert!(
            with_ctrl.stats.accuracy() >= no_ctrl.stats.accuracy() * 0.95,
            "controller must not destroy accuracy: {} vs {}",
            with_ctrl.stats.accuracy(),
            no_ctrl.stats.accuracy()
        );
    }

    #[test]
    fn topdown_buckets_populated() {
        let recs = trace("websearch", 50_000);
        let r = run_kind(&recs, PrefetcherKind::NextLineOnly);
        let t = &r.stats.topdown;
        assert!(t.retiring > 0.0);
        assert!(t.frontend > 0.0, "microservice trace must have I-stalls");
        assert!(t.backend > 0.0);
        assert!(t.bad_spec > 0.0);
        // Cycle accounting closes against the cycle counter.
        assert!((t.total() - r.stats.cycles).abs() <= 1.0 + r.stats.cycles * 1e-9);
    }

    #[test]
    fn bandwidth_accounted() {
        let recs = trace("kvstore-go", 50_000);
        let r = run_kind(&recs, PrefetcherKind::Eip { entries: 256 });
        assert!(r.stats.dram_bytes > 0);
        assert!(r.stats.dram_bytes_per_cycle() < 10.24, "cannot exceed channel");
    }

    #[test]
    fn ctx_segments_partition_the_run_without_perturbing_it() {
        let recs = trace("websearch", 60_000);
        let base = SimConfig {
            prefetcher: PrefetcherKind::Ceip { entries: 256, window: 8, whole_window: true },
            ..Default::default()
        };
        let plain = run(&base, &recs);
        assert!(plain.segments.is_none(), "segments tracked without opting in");
        let tracked = run(&SimConfig { track_segments: true, ..base }, &recs);
        // Observation only: identical timing and prefetch behavior.
        assert_eq!(tracked.stats.cycles, plain.stats.cycles);
        assert_eq!(tracked.stats.pf_issued, plain.stats.pf_issued);
        let segs = tracked.segments.expect("segments missing");
        // One segment per maximal ctx run: enough to fit a distribution,
        // and they exactly partition the cycle counter.
        let ctx_runs = 1 + recs.windows(2).filter(|w| w[0].ctx != w[1].ctx).count();
        assert_eq!(segs.len(), ctx_runs);
        assert!(segs.len() >= 16, "only {} ctx segments", segs.len());
        let total: f64 = segs.iter().sum();
        assert!(
            (total - tracked.stats.cycles).abs() <= 1.0 + tracked.stats.cycles * 1e-9,
            "segments {} do not partition cycles {}",
            total,
            tracked.stats.cycles
        );
        assert!(segs.iter().all(|s| *s >= 0.0));
    }

    #[test]
    fn compare_telemetry_observes_without_perturbing_the_run() {
        // DESIGN.md §12: compare mode records sketches and shadow-scores
        // decisions but must leave timing, prefetch behavior, and
        // controller stats bit-identical to the exact baseline.
        let recs = trace("websearch", 60_000);
        let base = SimConfig {
            prefetcher: PrefetcherKind::Ceip { entries: 256, window: 8, whole_window: true },
            controller: Some(ControllerCfg {
                train_interval_cycles: 100_000,
                ..Default::default()
            }),
            ..Default::default()
        };
        let plain = run(&base, &recs);
        assert!(plain.telemetry.is_none(), "telemetry allocated without opting in");
        let cmp = run(&SimConfig { telemetry: "compare".into(), ..base }, &recs);
        assert_eq!(cmp.stats.cycles, plain.stats.cycles);
        assert_eq!(cmp.stats.pf_issued, plain.stats.pf_issued);
        assert_eq!(cmp.stats.pf_skipped, plain.stats.pf_skipped);
        assert_eq!(
            cmp.controller.unwrap().issued,
            plain.controller.unwrap().issued
        );
        let t = cmp.telemetry.expect("telemetry missing");
        // Every issued prefetch was recorded (built-in next-line included).
        assert_eq!(t.issued.total(), cmp.stats.pf_issued);
        assert!(t.decisions_compared > 0);
        assert!(t.agreement().is_some());
        assert!(!t.exact_srcs.is_empty());
        assert!(t.contexts.estimate() > 0.0);
    }

    #[test]
    fn sketch_telemetry_is_rerun_deterministic_and_bounded() {
        let recs = trace("social", 60_000);
        let cfg = SimConfig {
            prefetcher: PrefetcherKind::Ceip { entries: 256, window: 8, whole_window: true },
            controller: Some(ControllerCfg {
                train_interval_cycles: 100_000,
                ..Default::default()
            }),
            telemetry: "sketch:w128d4p10k8".into(),
            ..Default::default()
        };
        let a = run(&cfg, &recs);
        let b = run(&cfg, &recs);
        assert_eq!(a.stats.cycles, b.stats.cycles);
        let (ta, tb) = (a.telemetry.unwrap(), b.telemetry.unwrap());
        assert_eq!(ta, tb, "sketch telemetry diverged across reruns");
        assert_eq!(ta.summary_json().dump(), tb.summary_json().dump());
        // Bounded memory: geometry-determined, independent of the trace.
        assert_eq!(ta.bytes(), 3 * 128 * 4 * 4 + 1024 + 8 * 16);
        assert!(ta.exact_srcs.is_empty(), "sketch mode must not track exact contexts");
    }

    #[test]
    fn cheip_runs_and_tracks_migrations() {
        let recs = trace("social", 150_000);
        let r = run_kind(
            &recs,
            PrefetcherKind::Cheip { vt_entries: 2048, window: 8, whole_window: true },
        );
        assert!(r.stats.pf_issued > 0, "CHEIP issued nothing");
        assert!(r.ipc() > 0.0);
        // §V budget: ~24.75 KB total.
        assert_eq!(r.metadata_bytes, 2304 + 22_272 + 624);
    }
}
