//! Simulation statistics: MPKI, prefetch accuracy/coverage/timeliness,
//! top-down cycle buckets (Fig 1), and bandwidth — the quantities every
//! figure in the paper's evaluation is built from.

/// Top-down breakdown (Fig 1): where cycles went.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TopDown {
    pub retiring: f64,
    pub frontend: f64,
    pub backend: f64,
    pub bad_spec: f64,
}

impl TopDown {
    pub fn total(&self) -> f64 {
        self.retiring + self.frontend + self.backend + self.bad_spec
    }

    /// Fractions summing to 1 (or zeros when empty).
    pub fn fractions(&self) -> [f64; 4] {
        let t = self.total();
        if t <= 0.0 {
            return [0.0; 4];
        }
        [
            self.retiring / t,
            self.frontend / t,
            self.backend / t,
            self.bad_spec / t,
        ]
    }
}

/// Counters accumulated by the engine during a run.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    pub instrs: u64,
    pub cycles: f64,
    // L1I demand behaviour.
    pub l1i_accesses: u64,
    /// Demand misses that no prefetch covered (full latency exposed).
    pub l1i_demand_misses: u64,
    /// Demand accesses converted to hits by a timely prefetch.
    pub pf_timely: u64,
    /// Demand accesses partially covered by a late prefetch.
    pub pf_late: u64,
    /// Prefetches issued.
    pub pf_issued: u64,
    /// Prefetched lines evicted before any demand use.
    pub pf_useless: u64,
    /// Demand misses on lines recently evicted by a prefetch fill
    /// (harmful evictions / pollution).
    pub pollution_misses: u64,
    /// Candidates suppressed by the ML controller.
    pub pf_skipped: u64,
    /// Shadow mode (§VI-A): candidates the controller *would* have issued,
    /// and the bandwidth they would have consumed.
    pub shadow_would_issue: u64,
    pub shadow_bytes: u64,
    /// Anomalous-miss-burst guardrail activations (§VII).
    pub anomaly_resets: u64,
    // L1D.
    pub l1d_accesses: u64,
    pub l1d_misses: u64,
    // Cycle buckets.
    pub topdown: TopDown,
    // Bandwidth.
    pub dram_bytes: u64,
    pub dram_transfers: u64,
}

impl SimStats {
    /// Instruction misses per kilo-instruction. Late-covered accesses still
    /// count as misses (the fetch stalled), timely-covered do not.
    pub fn mpki(&self) -> f64 {
        if self.instrs == 0 {
            return 0.0;
        }
        (self.l1i_demand_misses + self.pf_late) as f64 * 1000.0 / self.instrs as f64
    }

    pub fn l1d_mpki(&self) -> f64 {
        if self.instrs == 0 {
            return 0.0;
        }
        self.l1d_misses as f64 * 1000.0 / self.instrs as f64
    }

    pub fn ipc(&self) -> f64 {
        if self.cycles <= 0.0 {
            0.0
        } else {
            self.instrs as f64 / self.cycles
        }
    }

    /// Useful prefetches / issued prefetches (Fig 12).
    pub fn accuracy(&self) -> f64 {
        if self.pf_issued == 0 {
            return 0.0;
        }
        (self.pf_timely + self.pf_late) as f64 / self.pf_issued as f64
    }

    /// Fraction of would-be misses covered (timely or late).
    pub fn coverage(&self) -> f64 {
        let would_miss = self.l1i_demand_misses + self.pf_timely + self.pf_late;
        if would_miss == 0 {
            return 0.0;
        }
        (self.pf_timely + self.pf_late) as f64 / would_miss as f64
    }

    /// Of useful prefetches, the fraction that arrived on time.
    pub fn timeliness(&self) -> f64 {
        let useful = self.pf_timely + self.pf_late;
        if useful == 0 {
            return 0.0;
        }
        self.pf_timely as f64 / useful as f64
    }

    pub fn dram_bytes_per_cycle(&self) -> f64 {
        if self.cycles <= 0.0 {
            0.0
        } else {
            self.dram_bytes as f64 / self.cycles
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mpki_counts_late_as_miss() {
        let s = SimStats {
            instrs: 10_000,
            l1i_demand_misses: 50,
            pf_late: 10,
            pf_timely: 40,
            ..Default::default()
        };
        assert!((s.mpki() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn accuracy_and_coverage() {
        let s = SimStats {
            pf_issued: 100,
            pf_timely: 60,
            pf_late: 10,
            pf_useless: 30,
            l1i_demand_misses: 30,
            ..Default::default()
        };
        assert!((s.accuracy() - 0.7).abs() < 1e-9);
        assert!((s.coverage() - 0.7).abs() < 1e-9);
        assert!((s.timeliness() - 6.0 / 7.0).abs() < 1e-9);
    }

    #[test]
    fn zero_division_safe() {
        let s = SimStats::default();
        assert_eq!(s.mpki(), 0.0);
        assert_eq!(s.ipc(), 0.0);
        assert_eq!(s.accuracy(), 0.0);
        assert_eq!(s.coverage(), 0.0);
        assert_eq!(s.timeliness(), 0.0);
    }

    #[test]
    fn topdown_fractions_sum_to_one() {
        let t = TopDown {
            retiring: 25.0,
            frontend: 50.0,
            backend: 20.0,
            bad_spec: 5.0,
        };
        let f = t.fractions();
        assert!((f.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((f[1] - 0.5).abs() < 1e-12);
    }
}
