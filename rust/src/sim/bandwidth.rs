//! DRAM bandwidth model: a single-channel service queue (Table I: one
//! channel, 3200 MT/s = 25.6 GB/s = 10.24 B/cycle at 2.5 GHz).
//!
//! Every DRAM transfer (demand fill or prefetch fill) occupies the channel
//! for `bytes / bytes_per_cycle` cycles after a fixed access latency.
//! Over-aggressive prefetching therefore delays demand fills — the
//! mechanism behind the paper's bandwidth-cap concerns (§I challenge (ii),
//! §VI-A "budget caps").

#[derive(Clone, Debug)]
pub struct DramModel {
    /// Fixed access latency (row activation + CAS, cycles).
    pub latency: u64,
    /// Channel throughput.
    pub bytes_per_cycle: f64,
    /// Next cycle at which the channel is free.
    free_at: f64,
    /// Total bytes transferred (bandwidth accounting for reports).
    pub bytes_total: u64,
    /// Demand transfers that queued behind earlier transfers.
    pub queued_demand: u64,
    pub transfers: u64,
}

impl DramModel {
    pub fn new(latency: u64, bytes_per_cycle: f64) -> Self {
        DramModel {
            latency,
            bytes_per_cycle,
            free_at: 0.0,
            bytes_total: 0,
            queued_demand: 0,
            transfers: 0,
        }
    }

    /// Schedule a transfer of `bytes` starting no earlier than `now`.
    /// Returns the completion cycle.
    pub fn transfer(&mut self, now: u64, bytes: u32, is_demand: bool) -> u64 {
        let start = self.free_at.max(now as f64);
        if is_demand && start > now as f64 {
            self.queued_demand += 1;
        }
        let occupancy = bytes as f64 / self.bytes_per_cycle;
        self.free_at = start + occupancy;
        self.bytes_total += bytes as u64;
        self.transfers += 1;
        (start + self.latency as f64 + occupancy).ceil() as u64
    }

    /// Bandwidth headroom in [0,1]: 1 = idle channel, 0 = saturated
    /// (queue extends ≥ `horizon` cycles past `now`). A controller feature.
    pub fn headroom(&self, now: u64, horizon: f64) -> f64 {
        let backlog = (self.free_at - now as f64).max(0.0);
        (1.0 - backlog / horizon).clamp(0.0, 1.0)
    }

    /// Average bytes/cycle over the run.
    pub fn avg_bytes_per_cycle(&self, cycles: u64) -> f64 {
        if cycles == 0 {
            0.0
        } else {
            self.bytes_total as f64 / cycles as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn idle_channel_completes_at_latency_plus_occupancy() {
        let mut d = DramModel::new(90, 10.24);
        let done = d.transfer(1000, 64, true);
        // 64/10.24 = 6.25 → 1000 + 90 + 6.25 → ceil 1097.
        assert_eq!(done, 1097);
        assert_eq!(d.queued_demand, 0);
    }

    #[test]
    fn back_to_back_transfers_queue() {
        let mut d = DramModel::new(90, 10.24);
        let a = d.transfer(0, 64, true);
        let b = d.transfer(0, 64, true);
        assert!(b > a);
        assert_eq!(d.queued_demand, 1);
        assert_eq!(d.bytes_total, 128);
    }

    #[test]
    fn headroom_degrades_under_load() {
        let mut d = DramModel::new(90, 10.24);
        assert_eq!(d.headroom(0, 100.0), 1.0);
        for _ in 0..100 {
            d.transfer(0, 64, false);
        }
        assert!(d.headroom(0, 100.0) < 0.1);
        // After time passes, headroom recovers.
        assert!(d.headroom(100_000, 100.0) > 0.99);
    }

    #[test]
    fn channel_drains_with_time() {
        let mut d = DramModel::new(90, 10.24);
        d.transfer(0, 64, true);
        // Far in the future: no queueing.
        let done = d.transfer(10_000, 64, true);
        assert_eq!(done, 10_097);
        assert_eq!(d.queued_demand, 0, "non-overlapping transfers never queue");
    }

    #[test]
    fn avg_bandwidth() {
        let mut d = DramModel::new(90, 10.0);
        d.transfer(0, 100, true);
        assert!((d.avg_bytes_per_cycle(50) - 2.0).abs() < 1e-9);
    }
}
