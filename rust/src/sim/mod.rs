//! ZSim-like trace-driven timing simulation (the evaluation substrate —
//! see DESIGN.md "Substitutions" for the fidelity argument).

pub mod bandwidth;
pub mod cache;
pub mod engine;
pub mod inflight;
pub mod stats;
