//! Set-associative cache model with true-LRU replacement and an optional
//! per-slot metadata side-array (CHEIP attaches a compressed entry to each
//! L1-I line; metadata migrates with the line, §III-B).

use crate::config::CacheCfg;

/// Result of an insertion.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Evicted {
    pub line: u64,
    /// True when the victim slot was filled by a prefetch that was never
    /// demanded (the "useless fill" the controller penalizes).
    pub was_prefetch_unused: bool,
}

#[derive(Clone, Copy, Debug, Default)]
struct Slot {
    tag: u64,
    valid: bool,
    lru: u64,
    /// Filled by prefetch and not yet demanded.
    prefetched: bool,
}

/// Set-associative cache. Tags are full line addresses (simulator fidelity
/// beats tag-bit realism here; the *cost model* in `prefetch::budget` uses
/// the paper's bit counts).
/// Outcome of a demand access (rich form: the engine uses the
/// `prefetched` bit to claim in-flight entries without a map probe on the
/// hit path — §Perf L3 optimization #1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Access {
    Miss,
    Hit,
    /// Hit on a line that was filled by a prefetch and not yet demanded.
    HitPrefetched,
}

impl Access {
    #[inline]
    pub fn is_hit(self) -> bool {
        !matches!(self, Access::Miss)
    }
}

pub struct Cache {
    sets: u32,
    /// `sets - 1` when `sets` is a power of two (fast index mask).
    set_mask: Option<u64>,
    ways: u32,
    slots: Vec<Slot>,
    clock: u64,
    pub cfg: CacheCfg,
    // stats
    pub hits: u64,
    pub misses: u64,
    pub prefetch_fills: u64,
    pub useless_prefetch_evictions: u64,
}

impl Cache {
    pub fn new(cfg: CacheCfg) -> Self {
        let sets = cfg.sets();
        let ways = cfg.ways;
        Cache {
            sets,
            set_mask: sets.is_power_of_two().then(|| sets as u64 - 1),
            ways,
            slots: vec![Slot::default(); (sets * ways) as usize],
            clock: 0,
            cfg,
            hits: 0,
            misses: 0,
            prefetch_fills: 0,
            useless_prefetch_evictions: 0,
        }
    }

    #[inline]
    fn set_of(&self, line: u64) -> u32 {
        match self.set_mask {
            Some(m) => (line & m) as u32,
            None => (line % self.sets as u64) as u32,
        }
    }

    #[inline]
    fn set_slots(&mut self, set: u32) -> &mut [Slot] {
        let start = (set * self.ways) as usize;
        &mut self.slots[start..start + self.ways as usize]
    }

    /// Demand access: updates LRU; on hit clears the prefetched flag (the
    /// prefetch was useful) and reports whether it was set.
    pub fn access_rich(&mut self, line: u64) -> Access {
        self.clock += 1;
        let clock = self.clock;
        let set = self.set_of(line);
        for s in self.set_slots(set).iter_mut() {
            if s.valid && s.tag == line {
                s.lru = clock;
                let was_pf = s.prefetched;
                s.prefetched = false;
                self.hits += 1;
                return if was_pf { Access::HitPrefetched } else { Access::Hit };
            }
        }
        self.misses += 1;
        Access::Miss
    }

    /// Demand access: returns true on hit (boolean convenience form).
    #[inline]
    pub fn access(&mut self, line: u64) -> bool {
        self.access_rich(line).is_hit()
    }

    /// Probe without LRU update or stats (used by prefetch dedup).
    pub fn contains(&self, line: u64) -> bool {
        let set = self.set_of(line);
        let start = (set * self.ways) as usize;
        self.slots[start..start + self.ways as usize]
            .iter()
            .any(|s| s.valid && s.tag == line)
    }

    /// Insert a line (demand fill or prefetch fill). Returns the victim if
    /// a valid line was evicted. No-op if already present (refreshes LRU).
    /// Single pass over the set: presence, free way, and LRU victim are
    /// found together (§Perf L3).
    pub fn insert(&mut self, line: u64, is_prefetch: bool) -> Option<Evicted> {
        self.clock += 1;
        let clock = self.clock;
        let set = self.set_of(line);
        let slots = self.set_slots(set);
        let mut free: Option<usize> = None;
        let mut lru_idx = 0usize;
        let mut lru_min = u64::MAX;
        let mut found: Option<usize> = None;
        for (i, s) in slots.iter().enumerate() {
            if !s.valid {
                if free.is_none() {
                    free = Some(i);
                }
            } else if s.tag == line {
                found = Some(i);
                break;
            } else if s.lru < lru_min {
                lru_min = s.lru;
                lru_idx = i;
            }
        }
        if let Some(i) = found {
            slots[i].lru = clock;
            return None;
        }
        let victim_idx = free.unwrap_or(lru_idx);
        let victim = &mut slots[victim_idx];
        let evicted = if victim.valid {
            Some(Evicted {
                line: victim.tag,
                was_prefetch_unused: victim.prefetched,
            })
        } else {
            None
        };
        *victim = Slot {
            tag: line,
            valid: true,
            lru: clock,
            prefetched: is_prefetch,
        };
        if matches!(&evicted, Some(e) if e.was_prefetch_unused) {
            self.useless_prefetch_evictions += 1;
        }
        if is_prefetch {
            self.prefetch_fills += 1;
        }
        evicted
    }

    /// Invalidate a line if present; returns whether it was there.
    pub fn invalidate(&mut self, line: u64) -> bool {
        let set = self.set_of(line);
        for s in self.set_slots(set).iter_mut() {
            if s.valid && s.tag == line {
                s.valid = false;
                return true;
            }
        }
        false
    }

    /// Iterate over all resident lines (diagnostics).
    pub fn resident_lines(&self) -> impl Iterator<Item = u64> + '_ {
        self.slots.iter().filter(|s| s.valid).map(|s| s.tag)
    }

    pub fn capacity_lines(&self) -> u32 {
        self.sets * self.ways
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HierarchyCfg;
    use crate::util::prop;

    fn small_cfg(sets: u32, ways: u32) -> Cache {
        let mut c = Cache::new(CacheCfg {
            size_kb: sets * ways * 64 / 1024,
            ways,
            line_b: 64,
            latency: 1,
        });
        // size_kb arithmetic can floor to 0 for tiny caches; construct
        // directly instead.
        c.sets = sets;
        c.ways = ways;
        c.slots = vec![Slot::default(); (sets * ways) as usize];
        c
    }

    #[test]
    fn hit_after_insert() {
        let mut c = small_cfg(4, 2);
        assert!(!c.access(100));
        c.insert(100, false);
        assert!(c.access(100));
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_evicts_least_recent() {
        let mut c = small_cfg(1, 2);
        c.insert(1, false);
        c.insert(2, false);
        c.access(1); // 2 is now LRU
        let ev = c.insert(3, false).unwrap();
        assert_eq!(ev.line, 2);
        assert!(c.contains(1) && c.contains(3) && !c.contains(2));
    }

    #[test]
    fn prefetched_flag_cleared_on_demand_hit() {
        let mut c = small_cfg(1, 2);
        c.insert(7, true);
        assert!(c.access(7)); // demand hit clears flag
        c.insert(8, false);
        let ev = c.insert(9, false).unwrap();
        assert_eq!(ev.line, 7);
        assert!(!ev.was_prefetch_unused, "used prefetch must not count as useless");
    }

    #[test]
    fn unused_prefetch_eviction_counted() {
        let mut c = small_cfg(1, 1);
        c.insert(7, true);
        let ev = c.insert(8, false).unwrap();
        assert!(ev.was_prefetch_unused);
        assert_eq!(c.useless_prefetch_evictions, 1);
    }

    #[test]
    fn reinsert_refreshes_not_duplicates() {
        let mut c = small_cfg(1, 2);
        c.insert(1, false);
        c.insert(1, false);
        c.insert(2, false);
        assert!(c.insert(1, false).is_none()); // refresh, no eviction
        // 2 is older now.
        let ev = c.insert(3, false).unwrap();
        assert_eq!(ev.line, 2);
    }

    #[test]
    fn table1_l1i_geometry() {
        let c = Cache::new(HierarchyCfg::table1().l1i);
        assert_eq!(c.capacity_lines(), 512);
        assert_eq!(c.sets, 64);
    }

    #[test]
    fn invalidate_works() {
        let mut c = small_cfg(2, 2);
        c.insert(4, false);
        assert!(c.invalidate(4));
        assert!(!c.contains(4));
        assert!(!c.invalidate(4));
    }

    #[test]
    fn prop_capacity_never_exceeded_and_no_duplicates() {
        prop::check_unit(
            "cache invariants",
            40,
            prop::addr_stream(),
            |lines| {
                let mut c = small_cfg(4, 4);
                for &l in lines {
                    if !c.access(l) {
                        c.insert(l, l % 3 == 0);
                    }
                    let mut resident: Vec<u64> = c.resident_lines().collect();
                    assert!(resident.len() <= 16);
                    resident.sort_unstable();
                    let before = resident.len();
                    resident.dedup();
                    assert_eq!(before, resident.len(), "duplicate resident line");
                }
            },
        );
    }

    #[test]
    fn prop_most_recent_k_of_set_always_resident() {
        // For a single-set cache of W ways, the W most recently touched
        // distinct lines must all be resident (true-LRU property).
        prop::check_unit(
            "lru recency",
            30,
            prop::addr_stream(),
            |lines| {
                let ways = 4usize;
                let mut c = small_cfg(1, ways as u32);
                let mut recent: Vec<u64> = Vec::new();
                for &l in lines {
                    if !c.access(l) {
                        c.insert(l, false);
                    }
                    recent.retain(|&x| x != l);
                    recent.push(l);
                    let start = recent.len().saturating_sub(ways);
                    for &r in &recent[start..] {
                        assert!(c.contains(r), "recently used line {r} evicted early");
                    }
                }
            },
        );
    }
}
