//! In-flight prefetch tracking: models prefetch timeliness (paper Fig 3 —
//! "timely prefetching avoids late arrivals and early pollution").
//!
//! A prefetch issued at cycle C with fill latency L is *timely* for a
//! demand at C' ≥ C+L (fully hidden), *late* for C < C' < C+L (exposes the
//! residual L-(C'-C)), and *unused* if evicted before any demand.

use crate::util::hashfx::FxHashMap;

/// Outcome of matching a demand access against in-flight prefetches.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PrefetchMatch {
    /// No prefetch in flight for this line.
    None,
    /// Prefetch completed before the demand: full hit.
    Timely,
    /// Prefetch still in flight: demand stalls `residual` cycles.
    Late { residual: u64 },
}

/// Metadata kept per in-flight (or completed-but-unclaimed) prefetch.
#[derive(Clone, Copy, Debug)]
pub struct InflightEntry {
    pub ready_at: u64,
    /// Source (trigger) line — routed back to the prefetcher and the ML
    /// controller for confidence/reward updates.
    pub src: u64,
    /// Controller decision id (usize::MAX = not gated).
    pub decision: usize,
}

/// Tracks prefetches from issue until first demand use (or eviction).
#[derive(Default)]
pub struct Inflight {
    map: FxHashMap<u64, InflightEntry>,
}

impl Inflight {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register an issued prefetch. Returns false if one is already in
    /// flight for the line (duplicate issue — caller should not re-issue).
    pub fn issue(&mut self, line: u64, entry: InflightEntry) -> bool {
        if self.map.contains_key(&line) {
            return false;
        }
        self.map.insert(line, entry);
        true
    }

    pub fn contains(&self, line: u64) -> bool {
        self.map.contains_key(&line)
    }

    /// Match a demand access at `now`; removes the entry when matched.
    pub fn demand(&mut self, line: u64, now: u64) -> (PrefetchMatch, Option<InflightEntry>) {
        match self.map.remove(&line) {
            None => (PrefetchMatch::None, None),
            Some(e) => {
                if now >= e.ready_at {
                    (PrefetchMatch::Timely, Some(e))
                } else {
                    (
                        PrefetchMatch::Late {
                            residual: e.ready_at - now,
                        },
                        Some(e),
                    )
                }
            }
        }
    }

    /// Drop tracking for an evicted line (prefetched but never used).
    pub fn evict(&mut self, line: u64) -> Option<InflightEntry> {
        self.map.remove(&line)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(ready: u64) -> InflightEntry {
        InflightEntry {
            ready_at: ready,
            src: 1,
            decision: usize::MAX,
        }
    }

    #[test]
    fn timely_when_demand_after_ready() {
        let mut inf = Inflight::new();
        inf.issue(10, entry(100));
        let (m, e) = inf.demand(10, 150);
        assert_eq!(m, PrefetchMatch::Timely);
        assert_eq!(e.unwrap().src, 1);
        assert!(inf.is_empty());
    }

    #[test]
    fn late_exposes_residual() {
        let mut inf = Inflight::new();
        inf.issue(10, entry(100));
        let (m, _) = inf.demand(10, 60);
        assert_eq!(m, PrefetchMatch::Late { residual: 40 });
    }

    #[test]
    fn exact_boundary_is_timely() {
        let mut inf = Inflight::new();
        inf.issue(10, entry(100));
        let (m, _) = inf.demand(10, 100);
        assert_eq!(m, PrefetchMatch::Timely);
    }

    #[test]
    fn no_match_for_unknown_line() {
        let mut inf = Inflight::new();
        let (m, e) = inf.demand(99, 5);
        assert_eq!(m, PrefetchMatch::None);
        assert!(e.is_none());
    }

    #[test]
    fn duplicate_issue_rejected() {
        let mut inf = Inflight::new();
        assert!(inf.issue(10, entry(100)));
        assert!(!inf.issue(10, entry(200)));
        assert_eq!(inf.len(), 1);
    }

    #[test]
    fn evict_removes() {
        let mut inf = Inflight::new();
        inf.issue(10, entry(100));
        assert!(inf.evict(10).is_some());
        let (m, _) = inf.demand(10, 500);
        assert_eq!(m, PrefetchMatch::None);
    }
}
