//! Instruction/data trace substrate.
//!
//! The paper evaluates on traces collected from production microservices
//! (request admission, feature lookup, model dispatch, logging pipelines —
//! §X-A). Those traces are proprietary, so this module provides the
//! substitute documented in DESIGN.md: a synthetic generator
//! ([`gen`]) that reproduces the *layout statistics the paper's encoding
//! relies on* (20-bit source→destination deltas from shared-region code
//! layout, 8-line destination clustering from basic-block sequences and
//! fall-through chains), plus a compact binary codec ([`codec`]) and
//! stream analyzers ([`stats`]).
//!
//! Addresses in records are **cache-line addresses** (byte address >> 6),
//! matching the paper's 64 B lines (Table I).

pub mod codec;
pub mod gen;
pub mod stats;

/// What kind of access a record describes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// Instruction fetch of a cache line; `instrs` instructions are
    /// consumed sequentially from it before the next record.
    Fetch,
    /// Data read (exercises L1D/NLP and shares hierarchy bandwidth).
    Load,
    /// Data write.
    Store,
}

/// One trace record.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Record {
    pub kind: Kind,
    /// Cache-line address (byte addr >> 6).
    pub line: u64,
    /// Instructions consumed from this line (Fetch; 0 for Load/Store).
    pub instrs: u8,
    /// RPC/handler context tag (paper §IV-A "lightweight thread/RPC tag").
    pub ctx: u8,
}

impl Record {
    pub fn fetch(line: u64, instrs: u8, ctx: u8) -> Self {
        Record {
            kind: Kind::Fetch,
            line,
            instrs,
            ctx,
        }
    }

    pub fn load(line: u64, ctx: u8) -> Self {
        Record {
            kind: Kind::Load,
            line,
            instrs: 0,
            ctx,
        }
    }

    pub fn store(line: u64, ctx: u8) -> Self {
        Record {
            kind: Kind::Store,
            line,
            instrs: 0,
            ctx,
        }
    }
}

/// Trace-level metadata carried in file headers and reports.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceMeta {
    pub app: String,
    pub seed: u64,
    pub line_bytes: u32,
    pub records: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_constructors() {
        let f = Record::fetch(0x40, 16, 2);
        assert_eq!(f.kind, Kind::Fetch);
        assert_eq!(f.instrs, 16);
        let l = Record::load(7, 0);
        assert_eq!(l.kind, Kind::Load);
        assert_eq!(l.instrs, 0);
        let s = Record::store(9, 1);
        assert_eq!(s.kind, Kind::Store);
    }
}
