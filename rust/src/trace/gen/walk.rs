//! Control-flow walk over a synthetic [`Image`]: produces the instruction
//! (and data) access stream as an iterator of [`Record`]s.
//!
//! The walk models the steady-state fetch behaviour the paper describes
//! (§IX): hot basic-block sequences and fall-throughs (sequential line
//! fetches), short loops (backward branches), call/return regions (stack
//! walk over the call graph), and RPC dispatch (dispatcher → handler chain
//! per request, tagging records with the handler context). Phase churn
//! (canary rollouts / config toggles, §I "systems challenge (iii)") is
//! injected by [`super::churn::ChurnSchedule`].

use super::churn::ChurnSchedule;
use super::layout::Image;
use crate::trace::{Kind, Record};
use crate::util::rng::Rng;

/// Tunables for the walk (per-app presets set these).
#[derive(Clone, Debug)]
pub struct WalkParams {
    /// Probability a block falls through to the next block (vs branch).
    pub fall_through_p: f64,
    /// Probability of making a call after a block (if callees exist).
    pub call_p: f64,
    /// Maximum call depth (stack clamp).
    pub max_depth: usize,
    /// Probability of a data access per fetched block.
    pub data_access_p: f64,
    /// Fraction of data accesses that are stores.
    pub store_frac: f64,
    /// Requests per dispatcher loop iteration (handler chain length).
    pub chain_len: usize,
    /// Probability a call targets a uniformly random function instead of a
    /// call-graph callee (cold paths: allocator, error handling, logging
    /// helpers — this is what inflates microservice I-footprints, §II-A).
    pub cold_call_p: f64,
}

impl Default for WalkParams {
    fn default() -> Self {
        WalkParams {
            fall_through_p: 0.72,
            call_p: 0.35,
            max_depth: 24,
            data_access_p: 0.30,
            store_frac: 0.3,
            chain_len: 3,
            cold_call_p: 0.06,
        }
    }
}

/// Iterator yielding trace records from the control-flow walk.
pub struct Walk<'a> {
    img: &'a Image,
    p: WalkParams,
    rng: Rng,
    churn: ChurnSchedule,
    /// (function, block index) call stack.
    stack: Vec<(usize, usize)>,
    /// Current function / block.
    cur_fn: usize,
    cur_block: usize,
    /// Queued records not yet emitted (lines of the current block + data).
    queue: std::collections::VecDeque<Record>,
    /// Current RPC context tag.
    ctx: u8,
    /// Remaining handler-chain hops for the in-flight request.
    chain_left: usize,
    /// Backward-loop iterations taken in the current function visit
    /// (capped so short loops terminate — real loops have trip counts).
    loops_in_fn: u32,
    /// Records emitted so far (drives churn schedule).
    emitted: u64,
    /// Stop after this many records.
    limit: u64,
    /// Per-request record counts (for RPC-layer calibration).
    pub request_sizes: Vec<u32>,
    cur_request_size: u32,
}

impl<'a> Walk<'a> {
    pub fn new(
        img: &'a Image,
        params: WalkParams,
        churn: ChurnSchedule,
        seed: u64,
        limit: u64,
    ) -> Self {
        let mut w = Walk {
            img,
            p: params,
            rng: Rng::new(seed),
            churn,
            stack: Vec::new(),
            cur_fn: img.dispatcher,
            cur_block: 0,
            queue: std::collections::VecDeque::new(),
            ctx: 0,
            chain_left: 0,
            loops_in_fn: 0,
            emitted: 0,
            limit,
            request_sizes: Vec::new(),
            cur_request_size: 0,
        };
        w.enqueue_block();
        w
    }

    /// Push the lines of the current block (plus possible data accesses)
    /// into the emit queue.
    fn enqueue_block(&mut self) {
        let f = &self.img.functions[self.cur_fn];
        let b = &f.blocks[self.cur_block];
        for i in 0..b.lines {
            let last = i == b.lines - 1;
            let instrs = if last { b.tail_instrs } else { 16 };
            self.queue
                .push_back(Record::fetch(b.start + i as u64, instrs.max(1), self.ctx));
        }
        if self.rng.chance(self.p.data_access_p) {
            let dline = self.img.data_base + self.rng.below(self.img.data_lines);
            let rec = if self.rng.chance(self.p.store_frac) {
                Record::store(dline, self.ctx)
            } else {
                Record::load(dline, self.ctx)
            };
            self.queue.push_back(rec);
        }
    }

    /// Decide where control flows after the current block.
    fn advance_control(&mut self) {
        let f = &self.img.functions[self.cur_fn];
        let n_blocks = f.blocks.len();

        // Early return: functions can exit from any block (error paths,
        // guard clauses). Keeps per-visit residence bounded so the walk
        // regularly unwinds to the dispatcher.
        if !self.stack.is_empty() && self.rng.chance(0.10) {
            let (rf, rb) = self.stack.pop().unwrap();
            self.cur_fn = rf;
            let n = self.img.functions[rf].blocks.len();
            self.cur_block = (rb + 1).min(n - 1);
            self.loops_in_fn = 0;
            self.enqueue_block();
            return;
        }

        // Call? Probability decays with stack depth so the call tree is
        // subcritical (real services have bounded stack residence; without
        // this the branching process never returns to the dispatcher).
        let depth_frac = self.stack.len() as f64 / self.p.max_depth as f64;
        let eff_call_p = self.p.call_p * (1.0 - depth_frac) * (1.0 - depth_frac);
        if !f.callees.is_empty()
            && self.stack.len() < self.p.max_depth
            && self.rng.chance(eff_call_p)
        {
            let callee = if self.rng.chance(self.p.cold_call_p) {
                // Cold path: uniform over the whole image.
                self.rng.below(self.img.functions.len() as u64) as usize
            } else {
                let weights: Vec<f64> = f.callees.iter().map(|&(_, w)| w).collect();
                let pick = self.rng.weighted(&weights);
                self.churn.redirect(f.callees[pick].0, &mut self.rng)
            };
            let callee = callee.min(self.img.functions.len() - 1);
            self.stack.push((self.cur_fn, self.cur_block));
            self.cur_fn = callee;
            self.cur_block = 0;
            self.loops_in_fn = 0;
            self.enqueue_block();
            return;
        }

        // Short loop: branch back a few blocks (the paper's "short loop
        // indicator" feature keys off this). Trip counts are capped — real
        // loops terminate.
        if self.cur_block > 0 && self.loops_in_fn < 8 && self.rng.chance(f.loop_back_p) {
            self.loops_in_fn += 1;
            let back = 1 + self.rng.below(self.cur_block.min(3) as u64 + 1) as usize;
            self.cur_block = self.cur_block.saturating_sub(back);
            self.enqueue_block();
            return;
        }

        // Fall through or branch forward within the function.
        if self.cur_block + 1 < n_blocks {
            if self.rng.chance(self.p.fall_through_p) {
                self.cur_block += 1;
            } else {
                // Forward branch: skip 1-3 blocks (cold-path skip).
                let skip = 1 + self.rng.below(3) as usize;
                self.cur_block = (self.cur_block + skip).min(n_blocks - 1);
            }
            self.enqueue_block();
            return;
        }

        // Function end: return, or if stack empty, next RPC dispatch.
        if let Some((rf, rb)) = self.stack.pop() {
            self.cur_fn = rf;
            let n = self.img.functions[rf].blocks.len();
            self.cur_block = (rb + 1).min(n - 1);
            self.loops_in_fn = 0;
            self.enqueue_block();
        } else {
            self.dispatch_next();
        }
    }

    /// Dispatcher loop: pick the next handler in the chain (or start a new
    /// request), updating the RPC context tag.
    fn dispatch_next(&mut self) {
        if self.chain_left == 0 {
            // Request boundary.
            if self.cur_request_size > 0 {
                self.request_sizes.push(self.cur_request_size);
                self.cur_request_size = 0;
            }
            self.chain_left = self.p.chain_len;
            // Re-fetch dispatcher code between requests.
            self.cur_fn = self.img.dispatcher;
            self.cur_block = 0;
            self.ctx = 0;
            self.loops_in_fn = 0;
            self.enqueue_block();
            return;
        }
        self.chain_left -= 1;
        let h_idx = self
            .churn
            .pick_handler(self.img.handlers.len(), &mut self.rng);
        let handler = self
            .churn
            .redirect(self.img.handlers[h_idx], &mut self.rng)
            .min(self.img.functions.len() - 1);
        // Tag by handler identity (the paper's lightweight RPC tag).
        self.ctx = (h_idx + 1) as u8;
        self.cur_fn = handler;
        self.cur_block = 0;
        self.loops_in_fn = 0;
        self.enqueue_block();
    }
}

impl<'a> Iterator for Walk<'a> {
    type Item = Record;

    fn next(&mut self) -> Option<Record> {
        if self.emitted >= self.limit {
            return None;
        }
        while self.queue.is_empty() {
            self.advance_control();
        }
        let rec = self.queue.pop_front().unwrap();
        self.emitted += 1;
        self.cur_request_size += 1;
        self.churn.tick(self.emitted, &mut self.rng);
        if rec.kind == Kind::Fetch {
            Some(rec)
        } else {
            Some(rec)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::churn::ChurnSchedule;
    use crate::trace::gen::layout::{Image, LayoutParams};

    fn walk_records(n: u64, seed: u64) -> Vec<Record> {
        let mut rng = Rng::new(seed);
        let img = Image::build(&LayoutParams::default(), &mut rng);
        let img = Box::leak(Box::new(img));
        Walk::new(
            img,
            WalkParams::default(),
            ChurnSchedule::none(),
            seed,
            n,
        )
        .collect()
    }

    #[test]
    fn produces_exactly_limit_records() {
        assert_eq!(walk_records(10_000, 1).len(), 10_000);
    }

    #[test]
    fn deterministic_for_seed() {
        assert_eq!(walk_records(5_000, 2), walk_records(5_000, 2));
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(walk_records(5_000, 3), walk_records(5_000, 4));
    }

    #[test]
    fn mostly_fetches_with_some_data() {
        let recs = walk_records(50_000, 5);
        let fetches = recs.iter().filter(|r| r.kind == Kind::Fetch).count();
        let data = recs.len() - fetches;
        assert!(fetches > recs.len() * 7 / 10);
        assert!(data > 0);
    }

    #[test]
    fn sequential_runs_exist() {
        // Fall-through chains must produce +1 line deltas — the property
        // the 8-line window encoding (Fig 8) depends on.
        let recs = walk_records(50_000, 6);
        let fetch_lines: Vec<u64> = recs
            .iter()
            .filter(|r| r.kind == Kind::Fetch)
            .map(|r| r.line)
            .collect();
        let seq = fetch_lines
            .windows(2)
            .filter(|w| w[1] == w[0] + 1)
            .count();
        assert!(
            seq as f64 / fetch_lines.len() as f64 > 0.35,
            "sequential fraction too low: {}",
            seq as f64 / fetch_lines.len() as f64
        );
    }

    #[test]
    fn multiple_contexts_appear() {
        let recs = walk_records(100_000, 7);
        let mut ctxs: Vec<u8> = recs.iter().map(|r| r.ctx).collect();
        ctxs.sort_unstable();
        ctxs.dedup();
        assert!(ctxs.len() >= 3, "contexts: {ctxs:?}");
    }

    #[test]
    fn working_set_exceeds_l1i() {
        let recs = walk_records(200_000, 8);
        let mut lines: Vec<u64> = recs
            .iter()
            .filter(|r| r.kind == Kind::Fetch)
            .map(|r| r.line)
            .collect();
        lines.sort_unstable();
        lines.dedup();
        assert!(lines.len() > 512 * 2, "unique I-lines: {}", lines.len());
    }

    #[test]
    fn instrs_always_nonzero_on_fetch() {
        for r in walk_records(20_000, 9) {
            if r.kind == Kind::Fetch {
                assert!(r.instrs >= 1 && r.instrs <= 16);
            }
        }
    }
}
