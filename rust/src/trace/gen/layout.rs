//! Synthetic binary-image model: libraries → functions → basic blocks.
//!
//! The generator reproduces the code-layout phenomena the paper's
//! compressed entry exploits (§IX): function-local basic-block sequences
//! and short fall-through chains (destination clustering within a few
//! lines), library regions whose internal deltas fit in 20 line-address
//! LSBs, and occasional far regions (JIT/dlopen analogues) whose deltas do
//! not. All addresses are cache-line addresses.

use crate::util::rng::Rng;

/// A basic block: contiguous cache lines inside a function.
#[derive(Clone, Debug)]
pub struct Block {
    /// First line address of the block.
    pub start: u64,
    /// Length in lines (1..=4).
    pub lines: u32,
    /// Instructions in the final (possibly partial) line.
    pub tail_instrs: u8,
}

/// A function: a run of basic blocks plus control-flow metadata.
#[derive(Clone, Debug)]
pub struct Function {
    pub blocks: Vec<Block>,
    /// Indices into `Image::functions` this function may call, with weights.
    pub callees: Vec<(usize, f64)>,
    /// Probability a block ends in a backward branch (short loop).
    pub loop_back_p: f64,
    /// Library this function belongs to.
    pub library: usize,
    /// Handler/RPC context tag propagated into trace records.
    pub ctx: u8,
}

/// A library: a contiguous address region holding functions.
#[derive(Clone, Debug)]
pub struct Library {
    pub name: String,
    pub base_line: u64,
    pub end_line: u64,
}

/// The whole binary image.
#[derive(Clone, Debug)]
pub struct Image {
    pub libraries: Vec<Library>,
    pub functions: Vec<Function>,
    /// Entry dispatcher function index (the RPC loop).
    pub dispatcher: usize,
    /// Handler entry points (per RPC type).
    pub handlers: Vec<usize>,
    /// Data region base (loads/stores).
    pub data_base: u64,
    pub data_lines: u64,
}

/// Parameters controlling image construction (per-app presets set these).
#[derive(Clone, Debug)]
pub struct LayoutParams {
    pub libraries: usize,
    /// Functions per library.
    pub funcs_per_lib: usize,
    /// Mean blocks per function.
    pub mean_blocks: usize,
    /// Fraction of libraries placed in a "far" region whose delta from the
    /// main text region exceeds 20 line-address bits (JIT / dlopen model).
    pub far_lib_frac: f64,
    /// Mean callees per function.
    pub mean_callees: usize,
    /// Probability calls stay within the same library (call locality).
    pub intra_lib_call_p: f64,
    /// Number of distinct RPC handler types.
    pub handler_types: usize,
    /// Data footprint in lines.
    pub data_lines: u64,
}

impl Default for LayoutParams {
    fn default() -> Self {
        LayoutParams {
            libraries: 6,
            funcs_per_lib: 120,
            mean_blocks: 6,
            far_lib_frac: 0.15,
            mean_callees: 3,
            intra_lib_call_p: 0.75,
            handler_types: 4,
            data_lines: 1 << 16,
        }
    }
}

/// Main text region starts here (arbitrary but away from zero).
const TEXT_BASE: u64 = 0x0040_0000; // line address
/// Far regions (JIT/dlopen) start beyond a 20-bit line-delta from text.
const FAR_BASE: u64 = TEXT_BASE + (1 << 22);
/// Gap between libraries inside a region, in lines.
const LIB_GAP: u64 = 1 << 14;

impl Image {
    pub fn build(params: &LayoutParams, rng: &mut Rng) -> Image {
        let mut libraries = Vec::with_capacity(params.libraries);
        let mut functions: Vec<Function> = Vec::new();
        let mut lib_fn_ranges: Vec<(usize, usize)> = Vec::new();

        let n_far = ((params.libraries as f64 * params.far_lib_frac).round() as usize)
            .min(params.libraries.saturating_sub(1));
        let mut near_cursor = TEXT_BASE;
        let mut far_cursor = FAR_BASE;

        for lib_idx in 0..params.libraries {
            let far = lib_idx >= params.libraries - n_far;
            let cursor = if far { &mut far_cursor } else { &mut near_cursor };
            let base = *cursor;
            let fn_start = functions.len();
            let mut line = base;
            for _ in 0..params.funcs_per_lib {
                // Function-local blocks laid out contiguously: this is the
                // fall-through chain that produces 8-line clustering.
                let n_blocks = 1 + rng.below(params.mean_blocks as u64 * 2 - 1) as usize;
                let mut blocks = Vec::with_capacity(n_blocks);
                for _ in 0..n_blocks {
                    let lines = 1 + rng.below(3) as u32; // 1..=3 lines
                    blocks.push(Block {
                        start: line,
                        lines,
                        tail_instrs: 1 + rng.below(16) as u8,
                    });
                    line += lines as u64;
                }
                // Small inter-function padding (alignment holes).
                line += rng.below(2);
                functions.push(Function {
                    blocks,
                    callees: Vec::new(),
                    loop_back_p: 0.05 + rng.f64() * 0.2,
                    library: lib_idx,
                    ctx: 0,
                });
            }
            lib_fn_ranges.push((fn_start, functions.len()));
            libraries.push(Library {
                name: format!("lib{lib_idx}{}", if far { "_far" } else { "" }),
                base_line: base,
                end_line: line,
            });
            *cursor = line + LIB_GAP;
        }

        // Call graph: mostly intra-library, popularity-skewed (hot callees).
        let n_fns = functions.len();
        for i in 0..n_fns {
            let lib = functions[i].library;
            let (lo, hi) = lib_fn_ranges[lib];
            let n_callees = 1 + rng.below(params.mean_callees as u64 * 2 - 1) as usize;
            let mut callees = Vec::with_capacity(n_callees);
            for _ in 0..n_callees {
                let target = if rng.chance(params.intra_lib_call_p) {
                    lo + rng.zipf(hi - lo, 1.2)
                } else {
                    rng.zipf(n_fns, 1.1)
                };
                if target != i {
                    callees.push((target, 0.2 + rng.f64()));
                }
            }
            functions[i].callees = callees;
        }

        // Dispatcher = function 0; handlers = hot functions, one per RPC
        // type, tagged with their context id.
        let dispatcher = 0;
        let mut handlers = Vec::with_capacity(params.handler_types);
        let mut used = std::collections::HashSet::new();
        used.insert(dispatcher);
        for h in 0..params.handler_types {
            let lib = h % params.libraries;
            let (lo, hi) = lib_fn_ranges[lib];
            let mut f = lo + rng.zipf(hi - lo, 1.1);
            while used.contains(&f) {
                f = lo + rng.below((hi - lo) as u64) as usize;
            }
            used.insert(f);
            functions[f].ctx = (h + 1) as u8;
            handlers.push(f);
        }

        Image {
            libraries,
            functions,
            dispatcher,
            handlers,
            data_base: 0x4000_0000,
            data_lines: params.data_lines,
        }
    }

    /// Total code footprint in lines (sum of library extents).
    pub fn code_lines(&self) -> u64 {
        self.libraries
            .iter()
            .map(|l| l.end_line - l.base_line)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn image() -> Image {
        Image::build(&LayoutParams::default(), &mut Rng::new(1))
    }

    #[test]
    fn builds_expected_counts() {
        let p = LayoutParams::default();
        let img = image();
        assert_eq!(img.libraries.len(), p.libraries);
        assert_eq!(img.functions.len(), p.libraries * p.funcs_per_lib);
        assert_eq!(img.handlers.len(), p.handler_types);
    }

    #[test]
    fn blocks_are_contiguous_within_functions() {
        let img = image();
        for f in &img.functions {
            for pair in f.blocks.windows(2) {
                let end = pair[0].start + pair[0].lines as u64;
                assert!(pair[1].start >= end, "blocks overlap");
                assert!(pair[1].start - end <= 2, "blocks not fall-through-adjacent");
            }
        }
    }

    #[test]
    fn far_libraries_exceed_20bit_delta() {
        let img = image();
        let far: Vec<_> = img.libraries.iter().filter(|l| l.name.ends_with("_far")).collect();
        assert!(!far.is_empty());
        for l in far {
            assert!(l.base_line >> 20 != TEXT_BASE >> 20);
        }
    }

    #[test]
    fn near_libraries_share_high_bits_mostly() {
        let img = image();
        let near: Vec<_> = img
            .libraries
            .iter()
            .filter(|l| !l.name.ends_with("_far"))
            .collect();
        // All near libraries fit under FAR_BASE.
        for l in near {
            assert!(l.end_line < FAR_BASE);
        }
    }

    #[test]
    fn callees_exist_and_are_not_self() {
        let img = image();
        for (i, f) in img.functions.iter().enumerate() {
            for &(c, w) in &f.callees {
                assert!(c < img.functions.len());
                assert_ne!(c, i);
                assert!(w > 0.0);
            }
        }
    }

    #[test]
    fn footprint_vastly_exceeds_l1i() {
        // Paper §II-A: footprints exceed the 512-line L1I by orders of
        // magnitude.
        assert!(image().code_lines() > 512 * 8);
    }

    #[test]
    fn deterministic_for_seed() {
        let a = Image::build(&LayoutParams::default(), &mut Rng::new(9));
        let b = Image::build(&LayoutParams::default(), &mut Rng::new(9));
        assert_eq!(a.code_lines(), b.code_lines());
        assert_eq!(a.functions.len(), b.functions.len());
        assert_eq!(a.functions[37].blocks[0].start, b.functions[37].blocks[0].start);
    }
}
