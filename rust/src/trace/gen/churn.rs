//! Phase churn: canary rollouts and configuration toggles (paper §I
//! challenge (iii), §X-A "steady state phases and rollout transitions").
//!
//! Two mechanisms:
//! * **Function redirection** — a rollout replaces a fraction of call
//!   targets with their "v2" alias (a different address region), modeling
//!   binary releases that relocate hot code and invalidate learned
//!   correlations.
//! * **Handler-mix drift** — the RPC type distribution changes between
//!   phases, shifting which handler chains are hot.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ChurnSchedule {
    /// Records between phase toggles (0 = never).
    pub period: u64,
    /// Fraction of calls redirected during an "odd" phase.
    pub redirect_frac: f64,
    /// Offset (in function indices) applied to redirected calls.
    pub redirect_stride: usize,
    /// Handler-popularity weights per phase parity.
    even_weights: Vec<f64>,
    odd_weights: Vec<f64>,
    /// Current phase parity.
    odd_phase: bool,
    next_toggle: u64,
}

impl ChurnSchedule {
    /// No churn at all (steady state).
    pub fn none() -> Self {
        ChurnSchedule {
            period: 0,
            redirect_frac: 0.0,
            redirect_stride: 0,
            even_weights: vec![1.0],
            odd_weights: vec![1.0],
            odd_phase: false,
            next_toggle: u64::MAX,
        }
    }

    /// Periodic churn with the given intensity.
    pub fn periodic(period: u64, redirect_frac: f64, handlers: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        let even: Vec<f64> = (0..handlers.max(1)).map(|_| 0.2 + rng.f64()).collect();
        let odd: Vec<f64> = (0..handlers.max(1)).map(|_| 0.2 + rng.f64()).collect();
        ChurnSchedule {
            period,
            redirect_frac,
            redirect_stride: 17,
            even_weights: even,
            odd_weights: odd,
            odd_phase: false,
            next_toggle: period.max(1),
        }
    }

    /// Advance the schedule; flips phase when the toggle point is reached.
    #[inline]
    pub fn tick(&mut self, emitted: u64, _rng: &mut Rng) {
        if self.period > 0 && emitted >= self.next_toggle {
            self.odd_phase = !self.odd_phase;
            self.next_toggle = emitted + self.period;
        }
    }

    /// Possibly redirect a call target (only in the odd phase).
    #[inline]
    pub fn redirect(&self, target: usize, rng: &mut Rng) -> usize {
        if self.odd_phase && self.redirect_frac > 0.0 && rng.chance(self.redirect_frac) {
            // Deterministic-ish alias: shift within the function table.
            target.wrapping_add(self.redirect_stride)
        } else {
            target
        }
    }

    /// Pick a handler index according to the current phase's mix.
    #[inline]
    pub fn pick_handler(&self, n: usize, rng: &mut Rng) -> usize {
        if n == 0 {
            return 0;
        }
        let w = if self.odd_phase {
            &self.odd_weights
        } else {
            &self.even_weights
        };
        if w.len() < n {
            return rng.below(n as u64) as usize;
        }
        rng.weighted(&w[..n])
    }

    pub fn in_odd_phase(&self) -> bool {
        self.odd_phase
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_never_toggles_or_redirects() {
        let mut c = ChurnSchedule::none();
        let mut r = Rng::new(1);
        for i in 0..100_000 {
            c.tick(i, &mut r);
        }
        assert!(!c.in_odd_phase());
        assert_eq!(c.redirect(5, &mut r), 5);
    }

    #[test]
    fn periodic_toggles_phase() {
        let mut c = ChurnSchedule::periodic(100, 0.5, 4, 1);
        let mut r = Rng::new(2);
        let mut toggles = 0;
        let mut last = c.in_odd_phase();
        for i in 0..1000 {
            c.tick(i, &mut r);
            if c.in_odd_phase() != last {
                toggles += 1;
                last = c.in_odd_phase();
            }
        }
        assert!(toggles >= 8, "toggles {toggles}");
    }

    #[test]
    fn redirect_only_in_odd_phase() {
        let mut c = ChurnSchedule::periodic(10, 1.0, 4, 3);
        let mut r = Rng::new(3);
        assert_eq!(c.redirect(100, &mut r), 100); // even phase
        c.tick(10, &mut r); // flip to odd
        assert!(c.in_odd_phase());
        assert_eq!(c.redirect(100, &mut r), 117);
    }

    #[test]
    fn handler_mix_changes_between_phases() {
        let mut c = ChurnSchedule::periodic(1, 0.0, 4, 4);
        let mut r = Rng::new(5);
        let sample = |c: &ChurnSchedule, r: &mut Rng| {
            let mut counts = [0u32; 4];
            for _ in 0..20_000 {
                counts[c.pick_handler(4, r)] += 1;
            }
            counts
        };
        let even = sample(&c, &mut r);
        c.tick(1, &mut r);
        let odd = sample(&c, &mut r);
        let diff: i64 = even
            .iter()
            .zip(odd.iter())
            .map(|(a, b)| (*a as i64 - *b as i64).abs())
            .sum();
        assert!(diff > 1000, "mix barely changed: {even:?} vs {odd:?}");
    }
}
