//! The eleven named workloads of Fig 2, as generator presets.
//!
//! The paper stratifies its service mix by language runtime (C/C++, Java,
//! Go) and library stack (RPC, serialization, crypto) (§X-A). Each preset
//! tunes the layout/walk/churn parameters to produce a distinct I-footprint
//! and MPKI profile: managed runtimes get *far* code regions (JIT analogue
//! → more >20-bit deltas, lower Fig 7 share), logging/serde get long
//! fall-through chains (dense windows), crypto gets tight loops (small
//! footprint, low MPKI).

use super::churn::ChurnSchedule;
use super::layout::LayoutParams;
use super::walk::WalkParams;

/// Language runtime of a service (affects layout statistics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Runtime {
    Cpp,
    Java,
    Go,
}

/// A complete per-app generation spec.
#[derive(Clone, Debug)]
pub struct AppSpec {
    pub name: &'static str,
    pub runtime: Runtime,
    pub layout: LayoutParams,
    pub walk: WalkParams,
    /// Churn period in records (0 = steady state) and redirect fraction.
    pub churn_period: u64,
    pub churn_redirect: f64,
}

impl AppSpec {
    pub fn churn(&self, seed: u64) -> ChurnSchedule {
        if self.churn_period == 0 {
            ChurnSchedule::none()
        } else {
            ChurnSchedule::periodic(
                self.churn_period,
                self.churn_redirect,
                self.layout.handler_types,
                seed,
            )
        }
    }
}

fn layout(
    libraries: usize,
    funcs_per_lib: usize,
    mean_blocks: usize,
    far_frac: f64,
    handlers: usize,
) -> LayoutParams {
    LayoutParams {
        libraries,
        funcs_per_lib,
        mean_blocks,
        far_lib_frac: far_frac,
        mean_callees: 3,
        intra_lib_call_p: 0.75,
        handler_types: handlers,
        data_lines: 1 << 16,
    }
}

fn walk(fall_through: f64, call_p: f64, depth: usize, data_p: f64, chain: usize) -> WalkParams {
    WalkParams {
        fall_through_p: fall_through,
        call_p,
        max_depth: depth,
        data_access_p: data_p,
        store_frac: 0.3,
        chain_len: chain,
        // Big-footprint services take more cold paths; scaled with depth.
        cold_call_p: 0.03 + 0.002 * depth as f64,
    }
}

/// All eleven applications (Fig 2). Order is the reporting order.
pub fn all_apps() -> Vec<AppSpec> {
    vec![
        AppSpec {
            // Deep stack, huge footprint — the Fig 1 "web search binary".
            name: "websearch",
            runtime: Runtime::Cpp,
            layout: layout(8, 260, 7, 0.12, 6),
            walk: walk(0.68, 0.45, 28, 0.35, 4),
            churn_period: 400_000,
            churn_redirect: 0.25,
        },
        AppSpec {
            name: "social",
            runtime: Runtime::Cpp,
            layout: layout(7, 220, 6, 0.14, 5),
            walk: walk(0.70, 0.40, 24, 0.32, 4),
            churn_period: 500_000,
            churn_redirect: 0.2,
        },
        AppSpec {
            // Managed runtime: JIT regions far from main text.
            name: "retail-java",
            runtime: Runtime::Java,
            layout: layout(9, 240, 6, 0.33, 5),
            walk: walk(0.66, 0.42, 26, 0.34, 4),
            churn_period: 350_000,
            churn_redirect: 0.3,
        },
        AppSpec {
            name: "mlserve",
            runtime: Runtime::Cpp,
            layout: layout(6, 180, 8, 0.17, 4),
            walk: walk(0.74, 0.35, 20, 0.40, 3),
            churn_period: 600_000,
            churn_redirect: 0.15,
        },
        AppSpec {
            name: "featurestore-go",
            runtime: Runtime::Go,
            layout: layout(7, 200, 5, 0.28, 4),
            walk: walk(0.69, 0.38, 22, 0.42, 3),
            churn_period: 450_000,
            churn_redirect: 0.25,
        },
        AppSpec {
            // Control-plane admission: modest footprint, heavy RPC churn.
            name: "admission",
            runtime: Runtime::Cpp,
            layout: layout(5, 140, 5, 0.10, 6),
            walk: walk(0.71, 0.36, 18, 0.28, 5),
            churn_period: 300_000,
            churn_redirect: 0.3,
        },
        AppSpec {
            // Logging pipeline: long fall-through formatting chains.
            name: "logging",
            runtime: Runtime::Cpp,
            layout: layout(5, 160, 9, 0.08, 3),
            walk: walk(0.82, 0.25, 14, 0.36, 2),
            churn_period: 0,
            churn_redirect: 0.0,
        },
        AppSpec {
            // Crypto: tight loops over small hot code — lowest MPKI.
            name: "crypto",
            runtime: Runtime::Cpp,
            layout: layout(3, 60, 4, 0.05, 2),
            walk: walk(0.78, 0.18, 8, 0.45, 2),
            churn_period: 0,
            churn_redirect: 0.0,
        },
        AppSpec {
            // Serialization: dense sequential encode/decode loops.
            name: "serde",
            runtime: Runtime::Cpp,
            layout: layout(4, 120, 8, 0.07, 3),
            walk: walk(0.80, 0.28, 12, 0.38, 2),
            churn_period: 0,
            churn_redirect: 0.0,
        },
        AppSpec {
            name: "kvstore-go",
            runtime: Runtime::Go,
            layout: layout(6, 170, 5, 0.30, 4),
            walk: walk(0.70, 0.33, 18, 0.44, 3),
            churn_period: 550_000,
            churn_redirect: 0.2,
        },
        AppSpec {
            // A/B scheduler: branchy policy evaluation, frequent toggles.
            name: "abscheduler-java",
            runtime: Runtime::Java,
            layout: layout(8, 210, 5, 0.35, 6),
            walk: walk(0.62, 0.44, 24, 0.30, 5),
            churn_period: 250_000,
            churn_redirect: 0.35,
        },
    ]
}

/// Look up an app by name.
pub fn app(name: &str) -> Option<AppSpec> {
    all_apps().into_iter().find(|a| a.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exactly_eleven_apps() {
        assert_eq!(all_apps().len(), 11);
    }

    #[test]
    fn names_unique() {
        let apps = all_apps();
        let mut names: Vec<_> = apps.iter().map(|a| a.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 11);
    }

    #[test]
    fn lookup_works() {
        assert!(app("websearch").is_some());
        assert!(app("crypto").is_some());
        assert!(app("nonexistent").is_none());
    }

    #[test]
    fn managed_runtimes_have_more_far_code() {
        let apps = all_apps();
        let avg = |rt: Runtime| {
            let (s, n) = apps
                .iter()
                .filter(|a| a.runtime == rt)
                .fold((0.0, 0), |(s, n), a| (s + a.layout.far_lib_frac, n + 1));
            s / n as f64
        };
        assert!(avg(Runtime::Java) > avg(Runtime::Cpp));
        assert!(avg(Runtime::Go) > avg(Runtime::Cpp));
    }

    #[test]
    fn steady_state_apps_use_no_churn() {
        let a = app("crypto").unwrap();
        assert_eq!(a.churn_period, 0);
        assert!(!a.churn(1).in_odd_phase());
    }
}
