//! Synthetic microservice trace generation (the paper-trace substitute —
//! see DESIGN.md "Substitutions").

pub mod apps;
pub mod churn;
pub mod layout;
pub mod walk;

use crate::trace::{Record, TraceMeta};
use crate::util::rng::Rng;
use apps::AppSpec;
use layout::Image;
use walk::Walk;

/// Generate `limit` records for an app preset. Returns (meta, records,
/// per-request record sizes for the RPC layer).
pub fn generate(spec: &AppSpec, seed: u64, limit: u64) -> (TraceMeta, Vec<Record>, Vec<u32>) {
    let mut rng = Rng::new(seed);
    let img = Image::build(&spec.layout, &mut rng);
    let mut w = Walk::new(&img, spec.walk.clone(), spec.churn(seed), seed ^ 0x9E37, limit);
    let mut records = Vec::with_capacity(limit as usize);
    for r in &mut w {
        records.push(r);
    }
    let sizes = std::mem::take(&mut w.request_sizes);
    (
        TraceMeta {
            app: spec.name.to_string(),
            seed,
            line_bytes: 64,
            records: records.len() as u64,
        },
        records,
        sizes,
    )
}

/// Generate just the records (most callers).
pub fn generate_records(spec: &AppSpec, seed: u64, limit: u64) -> Vec<Record> {
    generate(spec, seed, limit).1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::Kind;

    #[test]
    fn generate_respects_limit_and_meta() {
        let spec = apps::app("logging").unwrap();
        let (meta, recs, sizes) = generate(&spec, 42, 20_000);
        assert_eq!(recs.len(), 20_000);
        assert_eq!(meta.records, 20_000);
        assert_eq!(meta.app, "logging");
        assert!(!sizes.is_empty(), "no request boundaries recorded");
    }

    #[test]
    fn apps_have_distinct_footprints() {
        let mut footprints = Vec::new();
        for name in ["websearch", "crypto", "logging"] {
            let spec = apps::app(name).unwrap();
            let recs = generate_records(&spec, 1, 100_000);
            let mut lines: Vec<u64> = recs
                .iter()
                .filter(|r| r.kind == Kind::Fetch)
                .map(|r| r.line)
                .collect();
            lines.sort_unstable();
            lines.dedup();
            footprints.push((name, lines.len()));
        }
        // websearch footprint must dwarf crypto's.
        assert!(footprints[0].1 > footprints[1].1 * 4, "{footprints:?}");
    }

    #[test]
    fn roundtrips_through_codec() {
        let spec = apps::app("serde").unwrap();
        let (meta, recs, _) = generate(&spec, 3, 5_000);
        let mut buf = Vec::new();
        crate::trace::codec::write_trace(&mut buf, &meta, recs.iter().copied(), 5_000).unwrap();
        let r = crate::trace::codec::TraceReader::new(std::io::Cursor::new(buf)).unwrap();
        let got: Vec<Record> = r.map(|x| x.unwrap()).collect();
        assert_eq!(got, recs);
    }
}
