//! Trace-stream analyzers: footprint, sequential-run, and delta statistics.
//!
//! These quantify the layout properties the paper's encoding depends on
//! and feed Fig 7/8-style analyses (the authoritative Fig 7/8 numbers come
//! from the instrumented EIP trainer during simulation; this module gives
//! the trace-level view used in reports and sanity tests).

use super::{Kind, Record};
use std::collections::HashMap;

#[derive(Clone, Debug, Default)]
pub struct TraceStats {
    pub records: u64,
    pub fetches: u64,
    pub loads: u64,
    pub stores: u64,
    pub instrs: u64,
    pub unique_ilines: u64,
    pub unique_dlines: u64,
    /// Fraction of consecutive fetch pairs with delta == +1.
    pub seq_frac: f64,
    /// Histogram of |fetch line delta| bucketed by bit-width (0..=44).
    pub delta_bits_hist: Vec<u64>,
    /// Fraction of fetch transitions whose delta fits in 20 bits of
    /// low-order addressing (shares high bits).
    pub fit20_frac: f64,
}

/// Single-pass analysis of a record stream.
pub fn analyze(records: &[Record]) -> TraceStats {
    let mut s = TraceStats {
        delta_bits_hist: vec![0u64; 45],
        ..Default::default()
    };
    let mut ilines: HashMap<u64, ()> = HashMap::new();
    let mut dlines: HashMap<u64, ()> = HashMap::new();
    let mut prev_fetch: Option<u64> = None;
    let mut seq = 0u64;
    let mut pairs = 0u64;
    let mut fit20 = 0u64;
    for r in records {
        s.records += 1;
        match r.kind {
            Kind::Fetch => {
                s.fetches += 1;
                s.instrs += r.instrs as u64;
                ilines.insert(r.line, ());
                if let Some(p) = prev_fetch {
                    pairs += 1;
                    if r.line == p + 1 {
                        seq += 1;
                    }
                    let delta = r.line.abs_diff(p);
                    let bits = 64 - delta.leading_zeros();
                    s.delta_bits_hist[(bits as usize).min(44)] += 1;
                    if crate::util::bits::shares_high_bits(p, r.line, 20) {
                        fit20 += 1;
                    }
                }
                prev_fetch = Some(r.line);
            }
            Kind::Load => {
                s.loads += 1;
                dlines.insert(r.line, ());
            }
            Kind::Store => {
                s.stores += 1;
                dlines.insert(r.line, ());
            }
        }
    }
    s.unique_ilines = ilines.len() as u64;
    s.unique_dlines = dlines.len() as u64;
    if pairs > 0 {
        s.seq_frac = seq as f64 / pairs as f64;
        s.fit20_frac = fit20 as f64 / pairs as f64;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::gen::{apps, generate_records};

    #[test]
    fn counts_kinds() {
        let recs = vec![
            Record::fetch(1, 16, 0),
            Record::fetch(2, 8, 0),
            Record::load(100, 0),
            Record::store(101, 0),
        ];
        let s = analyze(&recs);
        assert_eq!(s.fetches, 2);
        assert_eq!(s.loads, 1);
        assert_eq!(s.stores, 1);
        assert_eq!(s.instrs, 24);
        assert_eq!(s.unique_ilines, 2);
        assert_eq!(s.unique_dlines, 2);
        assert_eq!(s.seq_frac, 1.0);
    }

    #[test]
    fn generated_traces_mostly_fit_20_bits() {
        // The core layout property behind Fig 7: most deltas share high
        // bits above bit 20.
        let spec = apps::app("websearch").unwrap();
        let recs = generate_records(&spec, 11, 200_000);
        let s = analyze(&recs);
        assert!(s.fit20_frac > 0.80, "fit20 {}", s.fit20_frac);
        assert!(s.fit20_frac < 1.0, "far regions never crossed");
    }

    #[test]
    fn managed_runtime_has_lower_fit20() {
        let cpp = analyze(&generate_records(&apps::app("websearch").unwrap(), 5, 150_000));
        let java = analyze(&generate_records(
            &apps::app("abscheduler-java").unwrap(),
            5,
            150_000,
        ));
        assert!(
            java.fit20_frac < cpp.fit20_frac,
            "java {} vs cpp {}",
            java.fit20_frac,
            cpp.fit20_frac
        );
    }

    #[test]
    fn empty_stream() {
        let s = analyze(&[]);
        assert_eq!(s.records, 0);
        assert_eq!(s.seq_frac, 0.0);
    }
}
