//! Compact binary trace codec.
//!
//! Format (little-endian):
//! ```text
//!   magic   "SLFT"            4 bytes
//!   version u32               = 1
//!   line_bytes u32            = 64
//!   seed    u64
//!   app     u16 len + utf-8
//!   records u64               count
//!   stream: per record
//!     head byte: kind(2 LSBs) | has_ctx_change(bit 2) | instrs-follow(bit 3)
//!     zigzag-varint line delta vs previous record's line (any kind)
//!     [ctx u8 if changed]  [instrs u8 if !=16 for Fetch]
//! ```
//! Fetches dominated by +1 deltas and instrs==16 encode to 2 bytes.

use super::{Kind, Record, TraceMeta};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};

const MAGIC: &[u8; 4] = b"SLFT";
const VERSION: u32 = 1;

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

fn write_varint(out: &mut impl Write, mut v: u64) -> Result<()> {
    loop {
        let byte = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.write_all(&[byte])?;
            return Ok(());
        }
        out.write_all(&[byte | 0x80])?;
    }
}

fn read_varint(inp: &mut impl Read) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0;
    loop {
        let mut b = [0u8; 1];
        inp.read_exact(&mut b)?;
        v |= ((b[0] & 0x7F) as u64) << shift;
        if b[0] & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift >= 64 {
            bail!("varint too long");
        }
    }
}

/// Write a trace (meta + records) to any writer.
pub fn write_trace(
    w: &mut impl Write,
    meta: &TraceMeta,
    records: impl Iterator<Item = Record>,
    count_hint: u64,
) -> Result<u64> {
    let mut out = BufWriter::new(w);
    out.write_all(MAGIC)?;
    out.write_all(&VERSION.to_le_bytes())?;
    out.write_all(&meta.line_bytes.to_le_bytes())?;
    out.write_all(&meta.seed.to_le_bytes())?;
    let name = meta.app.as_bytes();
    out.write_all(&(name.len() as u16).to_le_bytes())?;
    out.write_all(name)?;
    // Record count is written up front from the hint; the reader trusts it.
    out.write_all(&count_hint.to_le_bytes())?;

    let mut prev_line = 0u64;
    let mut prev_ctx = 0u8;
    let mut written = 0u64;
    for r in records {
        let kind_bits = match r.kind {
            Kind::Fetch => 0u8,
            Kind::Load => 1,
            Kind::Store => 2,
        };
        let ctx_changed = r.ctx != prev_ctx;
        let nonstd_instrs = r.kind == Kind::Fetch && r.instrs != 16;
        let head = kind_bits | (u8::from(ctx_changed) << 2) | (u8::from(nonstd_instrs) << 3);
        out.write_all(&[head])?;
        write_varint(&mut out, zigzag(r.line as i64 - prev_line as i64))?;
        if ctx_changed {
            out.write_all(&[r.ctx])?;
            prev_ctx = r.ctx;
        }
        if nonstd_instrs {
            out.write_all(&[r.instrs])?;
        }
        prev_line = r.line;
        written += 1;
    }
    out.flush()?;
    if written != count_hint {
        bail!("record count mismatch: wrote {written}, hint {count_hint}");
    }
    Ok(written)
}

/// Streaming trace reader.
pub struct TraceReader<R: Read> {
    inp: BufReader<R>,
    pub meta: TraceMeta,
    remaining: u64,
    prev_line: u64,
    prev_ctx: u8,
}

impl<R: Read> TraceReader<R> {
    pub fn new(r: R) -> Result<Self> {
        let mut inp = BufReader::new(r);
        let mut magic = [0u8; 4];
        inp.read_exact(&mut magic).context("reading magic")?;
        if &magic != MAGIC {
            bail!("not a SLFT trace (bad magic)");
        }
        let mut u32b = [0u8; 4];
        inp.read_exact(&mut u32b)?;
        let version = u32::from_le_bytes(u32b);
        if version != VERSION {
            bail!("unsupported trace version {version}");
        }
        inp.read_exact(&mut u32b)?;
        let line_bytes = u32::from_le_bytes(u32b);
        let mut u64b = [0u8; 8];
        inp.read_exact(&mut u64b)?;
        let seed = u64::from_le_bytes(u64b);
        let mut u16b = [0u8; 2];
        inp.read_exact(&mut u16b)?;
        let name_len = u16::from_le_bytes(u16b) as usize;
        let mut name = vec![0u8; name_len];
        inp.read_exact(&mut name)?;
        inp.read_exact(&mut u64b)?;
        let records = u64::from_le_bytes(u64b);
        Ok(TraceReader {
            inp,
            meta: TraceMeta {
                app: String::from_utf8(name).context("app name utf-8")?,
                seed,
                line_bytes,
                records,
            },
            remaining: records,
            prev_line: 0,
            prev_ctx: 0,
        })
    }
}

impl<R: Read> Iterator for TraceReader<R> {
    type Item = Result<Record>;

    fn next(&mut self) -> Option<Self::Item> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let mut head = [0u8; 1];
        if let Err(e) = self.inp.read_exact(&mut head) {
            return Some(Err(e.into()));
        }
        let kind = match head[0] & 0b11 {
            0 => Kind::Fetch,
            1 => Kind::Load,
            2 => Kind::Store,
            _ => return Some(Err(anyhow::anyhow!("bad kind bits"))),
        };
        let delta = match read_varint(&mut self.inp) {
            Ok(v) => unzigzag(v),
            Err(e) => return Some(Err(e)),
        };
        let line = (self.prev_line as i64 + delta) as u64;
        self.prev_line = line;
        if head[0] & 0b100 != 0 {
            let mut c = [0u8; 1];
            if let Err(e) = self.inp.read_exact(&mut c) {
                return Some(Err(e.into()));
            }
            self.prev_ctx = c[0];
        }
        let instrs = if kind == Kind::Fetch {
            if head[0] & 0b1000 != 0 {
                let mut c = [0u8; 1];
                if let Err(e) = self.inp.read_exact(&mut c) {
                    return Some(Err(e.into()));
                }
                c[0]
            } else {
                16
            }
        } else {
            0
        };
        Some(Ok(Record {
            kind,
            line,
            instrs,
            ctx: self.prev_ctx,
        }))
    }
}

/// Convenience: write records to a file path.
pub fn write_trace_file(path: &std::path::Path, meta: &TraceMeta, records: &[Record]) -> Result<()> {
    let mut f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    write_trace(&mut f, meta, records.iter().copied(), records.len() as u64)?;
    Ok(())
}

/// Convenience: read an entire trace file.
pub fn read_trace_file(path: &std::path::Path) -> Result<(TraceMeta, Vec<Record>)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let reader = TraceReader::new(f)?;
    let meta = reader.meta.clone();
    let records: Result<Vec<_>> = reader.collect();
    Ok((meta, records?))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn meta(n: u64) -> TraceMeta {
        TraceMeta {
            app: "unit".into(),
            seed: 7,
            line_bytes: 64,
            records: n,
        }
    }

    #[test]
    fn zigzag_roundtrip() {
        for v in [0i64, 1, -1, i64::MAX, i64::MIN, 12345, -98765] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
    }

    #[test]
    fn varint_roundtrip() {
        let mut buf = Vec::new();
        let vals = [0u64, 1, 127, 128, 300, u64::MAX, 1 << 35];
        for v in vals {
            write_varint(&mut buf, v).unwrap();
        }
        let mut cur = std::io::Cursor::new(buf);
        for v in vals {
            assert_eq!(read_varint(&mut cur).unwrap(), v);
        }
    }

    #[test]
    fn empty_trace_roundtrip() {
        let mut buf = Vec::new();
        write_trace(&mut buf, &meta(0), std::iter::empty(), 0).unwrap();
        let r = TraceReader::new(std::io::Cursor::new(buf)).unwrap();
        assert_eq!(r.meta.app, "unit");
        assert_eq!(r.count(), 0);
    }

    #[test]
    fn trace_roundtrip_mixed_kinds() {
        let recs = vec![
            Record::fetch(100, 16, 0),
            Record::fetch(101, 16, 0),
            Record::load(50_000, 0),
            Record::fetch(102, 7, 3),
            Record::store(50_001, 3),
            Record::fetch(5, 16, 3),
        ];
        let mut buf = Vec::new();
        write_trace(&mut buf, &meta(recs.len() as u64), recs.iter().copied(), 6).unwrap();
        let r = TraceReader::new(std::io::Cursor::new(buf)).unwrap();
        let got: Vec<Record> = r.map(|x| x.unwrap()).collect();
        assert_eq!(got, recs);
    }

    #[test]
    fn sequential_fetches_are_two_bytes() {
        let recs: Vec<Record> = (0..1000).map(|i| Record::fetch(1000 + i, 16, 0)).collect();
        let mut buf = Vec::new();
        write_trace(&mut buf, &meta(1000), recs.iter().copied(), 1000).unwrap();
        let header = 4 + 4 + 4 + 8 + 2 + 4 + 8;
        // First record carries a larger delta; the rest are head+delta(+1).
        assert!(buf.len() <= header + 3 + 999 * 2, "len {}", buf.len());
    }

    #[test]
    fn count_mismatch_is_error() {
        let mut buf = Vec::new();
        let recs = vec![Record::fetch(1, 16, 0)];
        assert!(write_trace(&mut buf, &meta(1), recs.into_iter(), 2).is_err());
    }

    #[test]
    fn truncated_stream_is_error_not_panic() {
        let recs = vec![Record::fetch(100, 16, 0), Record::fetch(1 << 40, 16, 0)];
        let mut buf = Vec::new();
        write_trace(&mut buf, &meta(2), recs.into_iter(), 2).unwrap();
        buf.truncate(buf.len() - 2);
        let r = TraceReader::new(std::io::Cursor::new(buf)).unwrap();
        let out: Vec<_> = r.collect();
        assert!(out.last().unwrap().is_err());
    }

    #[test]
    fn prop_random_traces_roundtrip() {
        prop::check_unit(
            "codec roundtrip",
            60,
            |r: &mut Rng, size| {
                (0..size * 3)
                    .map(|_| {
                        let line = r.range(0, 1 << 44);
                        match r.below(3) {
                            0 => Record::fetch(line, r.range(1, 17) as u8, r.below(8) as u8),
                            1 => Record::load(line, r.below(8) as u8),
                            _ => Record::store(line, r.below(8) as u8),
                        }
                    })
                    .collect::<Vec<_>>()
            },
            |recs| {
                let mut buf = Vec::new();
                write_trace(
                    &mut buf,
                    &meta(recs.len() as u64),
                    recs.iter().copied(),
                    recs.len() as u64,
                )
                .unwrap();
                let r = TraceReader::new(std::io::Cursor::new(buf)).unwrap();
                let got: Vec<Record> = r.map(|x| x.unwrap()).collect();
                assert_eq!(&got, recs);
            },
        );
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("slofetch_codec_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.slft");
        let recs = vec![Record::fetch(42, 16, 1), Record::load(7, 1)];
        write_trace_file(&path, &meta(2), &recs).unwrap();
        let (m, got) = read_trace_file(&path).unwrap();
        assert_eq!(m.app, "unit");
        assert_eq!(got, recs);
        std::fs::remove_file(&path).ok();
    }
}
