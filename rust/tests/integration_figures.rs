//! Figure-shape integration tests: run the (reduced-scale) experiment
//! matrix and assert the paper's qualitative results hold — who wins, by
//! roughly what factor, where the orderings fall (DESIGN.md §5).

use slofetch::figures::{self, FigureCtx, Matrix};
use std::sync::OnceLock;

fn matrix() -> &'static Matrix {
    static M: OnceLock<Matrix> = OnceLock::new();
    M.get_or_init(|| {
        Matrix::compute(FigureCtx {
            records_per_app: 150_000,
            ..FigureCtx::quick()
        })
    })
}

#[test]
fn fig2_shape_mpki_ordering() {
    let m = matrix();
    let mpki = |app: &str| m.get(app, "nl").stats.mpki();
    // Deep-stack services dwarf crypto (the paper's motivation).
    assert!(mpki("websearch") > 4.0 * mpki("crypto"));
    assert!(mpki("retail-java") > 2.0 * mpki("crypto"));
    // Every app has a nonzero I-MPKI.
    for app in m.apps.iter().map(|a| a.name) {
        assert!(mpki(app) > 0.0, "{app} has zero MPKI");
    }
}

#[test]
fn fig6_shape_perfect_bounds_eip() {
    let m = matrix();
    for app in m.apps.iter().map(|a| a.name) {
        let eip = m.speedup(app, "eip256");
        let perfect = m.speedup(app, "perfect");
        assert!(
            perfect >= eip - 0.01,
            "{app}: perfect {perfect} below eip {eip}"
        );
    }
    assert!(m.geomean_speedup("perfect") > m.geomean_speedup("eip256"));
}

#[test]
fn fig7_shape_most_pairs_fit_20_bits() {
    let m = matrix();
    for app in m.apps.iter().map(|a| a.name) {
        let f = m.get(app, "ceip256").pair_stats.fit20_frac();
        assert!(f > 0.6, "{app}: fit20 {f}");
    }
    // Managed runtimes have more far (JIT) code → lower fit20.
    let java = m.get("abscheduler-java", "ceip256").pair_stats.fit20_frac();
    let cpp = m.get("logging", "ceip256").pair_stats.fit20_frac();
    assert!(java < cpp, "java {java} !< cpp {cpp}");
}

#[test]
fn fig8_shape_window_covers_most_destinations() {
    let m = matrix();
    for app in m.apps.iter().map(|a| a.name) {
        let f = m.get(app, "eip256").pair_stats.window_frac();
        assert!(f > 0.5, "{app}: window coverage {f}");
    }
}

#[test]
fn fig9_shape_ceip_slightly_below_eip() {
    let m = matrix();
    let eip = m.geomean_speedup("eip256");
    let ceip = m.geomean_speedup("ceip256");
    assert!(eip > 1.0 && ceip > 1.0, "both must beat NL: {eip} {ceip}");
    // CEIP below EIP (compression loses some destinations)…
    assert!(ceip <= eip + 1e-6, "ceip {ceip} above eip {eip}");
    // …but by a few percentage points of speedup (paper §X-C: "CEIP 256
    // is on average 2.3% below EIP 256 in speedup").
    let deficit_pp = (eip - ceip) * 100.0;
    assert!(
        (0.0..5.0).contains(&deficit_pp),
        "CEIP speedup deficit out of band: {deficit_pp}pp"
    );
}

#[test]
fn fig10_shape_reduction_tracks_uncovered() {
    let m = matrix();
    // Apps with more uncovered destinations should lose more speedup;
    // check the extremes rather than full rank correlation at small scale.
    let mut pts: Vec<(f64, f64)> = m
        .apps
        .iter()
        .map(|a| {
            let unc = m.get(a.name, "ceip256").pair_stats.uncovered_frac();
            let eip = m.speedup(a.name, "eip256") - 1.0;
            let ceip = m.speedup(a.name, "ceip256") - 1.0;
            let red = if eip > 1e-3 { (eip - ceip) / eip } else { 0.0 };
            (unc, red)
        })
        .collect();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let lo_third: f64 = pts[..3].iter().map(|p| p.1).sum::<f64>() / 3.0;
    let hi_third: f64 = pts[pts.len() - 3..].iter().map(|p| p.1).sum::<f64>() / 3.0;
    assert!(
        hi_third >= lo_third - 0.05,
        "high-uncovered apps lose less: lo {lo_third} hi {hi_third}"
    );
}

#[test]
fn fig11_shape_mpki_reductions_positive() {
    let m = matrix();
    let mut pos = 0;
    let mut total = 0;
    for app in m.apps.iter().map(|a| a.name) {
        let base = m.get(app, "nl").stats.mpki();
        for cfg in ["eip256", "ceip256", "cheip2k"] {
            total += 1;
            if m.get(app, cfg).stats.mpki() < base {
                pos += 1;
            }
        }
    }
    assert!(
        pos as f64 / total as f64 > 0.8,
        "only {pos}/{total} (app, cfg) pairs reduce MPKI"
    );
}

#[test]
fn fig12_shape_ceip_accuracy_above_eip() {
    let m = matrix();
    let mean_acc = |cfg: &str| {
        m.apps
            .iter()
            .map(|a| m.get(a.name, cfg).stats.accuracy())
            .sum::<f64>()
            / m.apps.len() as f64
    };
    let eip = mean_acc("eip256");
    let ceip = mean_acc("ceip256");
    assert!(
        ceip > eip,
        "paper Fig 12: CEIP concentrates on dense regions: ceip {ceip} !> eip {eip}"
    );
}

#[test]
fn fig13_shape_compressed_state_is_smaller_speedup_close() {
    let m = matrix();
    let app = m.apps[0].name;
    let eip_bytes = m.get(app, "eip256").metadata_bytes;
    let ceip_bytes = m.get(app, "ceip256").metadata_bytes;
    let cheip_bytes = m.get(app, "cheip2k").metadata_bytes;
    assert!(ceip_bytes * 3 < eip_bytes, "compression ratio lost");
    assert_eq!(cheip_bytes, 25_200, "§V budget (24.75 KB + history)");
    // CHEIP-2K keeps most of CEIP-128's speedup (same vtable capacity).
    let ceip128 = m.geomean_speedup("ceip128");
    let cheip2k = m.geomean_speedup("cheip2k");
    assert!(
        cheip2k > 1.0 && cheip2k > (ceip128 - 1.0) * 0.5 + 1.0,
        "virtualization lost too much: cheip2k {cheip2k} vs ceip128 {ceip128}"
    );
}

#[test]
fn rpc_tails_narrow_with_prefetching() {
    let m = matrix();
    let t = figures::rpc_tails(m);
    // Parse P99 column (index 3) for nl (row 0) and ceip256 (row 2).
    let p99 = |row: usize| t.rows[row][3].parse::<f64>().unwrap();
    let nl = p99(0);
    let ceip = p99(2);
    assert!(
        ceip < nl,
        "paper §XI: prefetching must narrow P99: ceip {ceip} !< nl {nl}"
    );
}

#[test]
fn all_figure_tables_render() {
    let m = matrix();
    for t in [
        figures::table1(),
        figures::fig1(m),
        figures::fig2(m),
        figures::fig6(m),
        figures::fig7(m),
        figures::fig8(m),
        figures::fig9(m),
        figures::fig10(m),
        figures::fig11(m),
        figures::fig12(m),
        figures::fig13(m),
        figures::summary(m),
    ] {
        let md = t.markdown();
        assert!(md.contains("###"), "{} renders", t.id);
        assert!(!t.rows.is_empty(), "{} has rows", t.id);
    }
}
