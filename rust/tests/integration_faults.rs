//! Fault-injection integration tests (DESIGN.md §14): (a) the shipped
//! `examples/cluster_faults.json` spec runs its crash/gray/brownout
//! schedule with stdout-surface results identical across `--threads`
//! values, (b) stripping the fault section reproduces the plain
//! `examples/cluster.json` spec exactly — the faults-off byte-identity
//! contract, (c) exhausting a retry budget completes the run as an SLO
//! miss (never a hang), (d) hedged dispatch picks a seed-stable winner,
//! and (e) randomized faulted runs agree bit-for-bit across the
//! calendar and heap scheduler backends, stale discards included.

use slofetch::cluster::{
    self, engine, ClientPolicySpec, ClusterSpec, EdgePolicy, FaultsSpec, ResolvedTopology,
    RunParams, SchedKind, TrafficShape,
};
use slofetch::obs::ObsCfg;
use slofetch::util::prop;
use std::path::Path;

fn example_spec(name: &str) -> ClusterSpec {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("../examples/{name}"));
    ClusterSpec::load(&path).unwrap_or_else(|e| panic!("examples/{name} must load: {e:#}"))
}

#[test]
fn faulted_example_spec_is_thread_invariant() {
    let mut spec = example_spec("cluster_faults.json");
    assert!(!spec.faults.is_empty(), "the shipped fault spec declares no faults");
    spec.requests = 20_000; // keep the integration run quick
    let a = cluster::run_spec(&spec, 1).unwrap();
    let b = cluster::run_spec(&spec, 8).unwrap();
    assert_eq!(
        cluster::report(&a).markdown(),
        cluster::report(&b).markdown(),
        "faulted cluster output depends on --threads"
    );
    for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
        assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}|{}", x.label, x.traffic);
        assert_eq!(x.events, y.events, "{}|{}", x.label, x.traffic);
        assert_eq!(x.fault_stats, y.fault_stats, "{}|{}", x.label, x.traffic);
        assert_eq!(x.requests, spec.requests, "{}: lost requests under faults", x.label);
    }
    // The schedule actually bit: crashes were processed and the fault
    // table renders identically on both runs.
    assert!(
        a.scenarios.iter().any(|s| s.fault_stats.crashes > 0),
        "no scenario processed a crash — the shipped schedule never fires"
    );
    let fa = cluster::fault_report(&a).expect("fault table missing");
    let fb = cluster::fault_report(&b).expect("fault table missing");
    assert_eq!(fa.markdown(), fb.markdown());
}

#[test]
fn faults_off_reproduces_the_plain_spec_exactly() {
    // cluster_faults.json is cluster.json plus a `faults` section: with
    // the section stripped (what `--faults off` does) the two specs
    // must serialize byte-identically, so every downstream run — and
    // the campaign content hash — is unchanged by the fault axis.
    let mut stripped = example_spec("cluster_faults.json");
    stripped.faults = FaultsSpec::default();
    let plain = example_spec("cluster.json");
    assert_eq!(
        stripped.to_json().dump(),
        plain.to_json().dump(),
        "faults-off spec diverged from the pre-fault example"
    );
    // And a faults-free run keeps every fault counter at zero and emits
    // no fault table: the healthy stdout surface is untouched.
    stripped.requests = 6_000;
    let out = cluster::run_spec(&stripped, 4).unwrap();
    for s in &out.scenarios {
        assert!(s.fault_stats.is_zero(), "{}: healthy run bumped fault counters", s.label);
    }
    assert!(cluster::fault_report(&out).is_none(), "fault table rendered on a healthy run");
}

fn two_stage_chain() -> ResolvedTopology {
    ResolvedTopology::chain_from_ipcs(
        &[("gw".into(), 2.0), ("be".into(), 2.0)],
        25_000.0,
        0.35,
        2.5,
    )
}

#[test]
fn retry_budget_exhaustion_is_an_slo_miss_not_a_hang() {
    // A brownout makes `be` ~40× slower than the client timeout for
    // essentially the whole run: every attempt times out, the single
    // retry times out too, and the stage must fail — the request
    // completes as an SLO miss. The test finishing at all is the no-hang
    // claim; the counters pin down the path it took.
    let topo = two_stage_chain();
    let lambda = topo.bottleneck_rate() * 0.5;
    let params =
        RunParams { requests: 4_000, seed: 11, slo_us: 60.0, base_rate_per_us: lambda };
    let faults = FaultsSpec {
        events: vec!["brownout:be:40:1:400000".into()],
        client: vec![ClientPolicySpec {
            service: "be".into(),
            policy: EdgePolicy {
                timeout_us: Some(30.0),
                retries: 1,
                backoff_us: 5.0,
                hedge_after_us: None,
            },
        }],
    };
    let r = engine::run_faults(
        &topo,
        &TrafficShape::Poisson { util: 1.0 },
        &params,
        None,
        Some(&faults),
    )
    .unwrap();
    assert_eq!(r.requests, 4_000, "requests lost under retry exhaustion");
    assert!(r.fault_stats.timeouts > 0, "no timeout ever fired");
    assert!(r.fault_stats.retries > 0, "no retry was attempted");
    assert!(r.fault_stats.failed > 0, "retry budget never exhausted");
    assert!(
        r.compliance < 1.0,
        "abandoned stages must surface as SLO misses (compliance {})",
        r.compliance
    );
    // Failed stages carry their elapsed time, so the tail reflects the
    // timeout chain rather than collapsing to zero.
    assert!(r.p99_us > params.slo_us, "p99 {} under a failing backend", r.p99_us);
}

#[test]
fn hedged_winner_is_seed_stable_across_backends() {
    // Replica 0 of `be` is gray (6× slow) for the whole run; hedges
    // fire 12 µs in and the duplicate usually lands on a healthy
    // replica and wins, turning the slow twin into a stale discard.
    // The winner choice must be a pure function of the seed: reruns and
    // backend swaps reproduce every counter and latency bit.
    let mut topo = two_stage_chain();
    topo.services[1].replicas = 3;
    let lambda = topo.bottleneck_rate() * 0.5;
    let params =
        RunParams { requests: 6_000, seed: 23, slo_us: 200.0, base_rate_per_us: lambda };
    let faults = FaultsSpec {
        events: vec!["gray:be:1:6:1:2000000".into()],
        client: vec![ClientPolicySpec {
            service: "be".into(),
            policy: EdgePolicy {
                timeout_us: None,
                retries: 0,
                backoff_us: 0.0,
                hedge_after_us: Some(12.0),
            },
        }],
    };
    let run = |sched: SchedKind| {
        engine::run_obs_sched_faults(
            &topo,
            &TrafficShape::Poisson { util: 1.0 },
            &params,
            None,
            &ObsCfg::off(),
            sched,
            Some(&faults),
        )
        .unwrap()
    };
    let a = run(SchedKind::Calendar);
    assert!(a.fault_stats.hedges > 0, "no hedge ever fired");
    assert!(a.fault_stats.stale_events > 0, "no losing twin was discarded");
    assert_eq!(a.requests, 6_000);
    let b = run(SchedKind::Calendar);
    assert_eq!(a.p99_us.to_bits(), b.p99_us.to_bits(), "hedge winner is not seed-stable");
    assert_eq!(a.fault_stats, b.fault_stats);
    assert_eq!(a.events, b.events);
    let h = run(SchedKind::Heap);
    assert_eq!(a.p99_us.to_bits(), h.p99_us.to_bits(), "backends disagree under hedging");
    assert_eq!(a.fault_stats, h.fault_stats);
    assert_eq!(a.events, h.events);
}

#[test]
fn prop_faulted_runs_agree_across_scheduler_backends() {
    // Randomized fault pressure: every (seed, utilization, timeout,
    // hedge) draw must produce bit-identical results — stale discards
    // included — on the calendar queue and the heap oracle. This is the
    // §13 equivalence contract extended to lazily-cancelled events.
    let gen = |r: &mut slofetch::util::rng::Rng, _size: usize| {
        (
            r.next_u64(),
            0.3 + r.f64() * 0.4,          // utilization 0.3..0.7
            20.0 + r.f64() * 60.0,        // timeout 20..80 µs
            5.0 + r.f64() * 10.0,         // hedge 5..15 µs
        )
    };
    prop::check_unit("faulted scheduler equivalence", 12, gen, |&(seed, util, to, hedge)| {
        let mut topo = two_stage_chain();
        topo.services[1].replicas = 2;
        let lambda = topo.bottleneck_rate() * util;
        let params =
            RunParams { requests: 2_000, seed, slo_us: 120.0, base_rate_per_us: lambda };
        let faults = FaultsSpec {
            events: vec![
                "down:be:0:5000:8000".into(),
                "downrate:be:40000:6000".into(),
                "gray:gw:1:3:2000:30000".into(),
            ],
            client: vec![ClientPolicySpec {
                service: "be".into(),
                policy: EdgePolicy {
                    timeout_us: Some(to),
                    retries: 2,
                    backoff_us: 4.0,
                    hedge_after_us: Some(hedge),
                },
            }],
        };
        let run = |sched: SchedKind| {
            engine::run_obs_sched_faults(
                &topo,
                &TrafficShape::Poisson { util: 1.0 },
                &params,
                None,
                &ObsCfg::off(),
                sched,
                Some(&faults),
            )
            .unwrap()
        };
        let cal = run(SchedKind::Calendar);
        let heap = run(SchedKind::Heap);
        assert_eq!(cal.p99_us.to_bits(), heap.p99_us.to_bits());
        assert_eq!(cal.mean_us.to_bits(), heap.mean_us.to_bits());
        assert_eq!(cal.events, heap.events);
        assert_eq!(cal.fault_stats, heap.fault_stats);
        assert_eq!(cal.requests, 2_000, "requests lost under random faults");
    });
}
