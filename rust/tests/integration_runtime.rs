//! Runtime integration: the AOT artifacts (JAX/Pallas → HLO text) must
//! compute exactly what the native Rust mirror computes. This is the
//! load-bearing test of the three-layer architecture: if it passes, the
//! controller math running on the request path (native) and the math
//! trained via PJRT are interchangeable.
//!
//! Requires `make artifacts` (the Makefile `test` target guarantees it).

use slofetch::ml::features::DIM;
use slofetch::ml::logistic::Weights;
use slofetch::runtime::{artifacts_dir, PjrtEngine};
use slofetch::util::rng::Rng;

fn engine() -> PjrtEngine {
    PjrtEngine::load(&artifacts_dir()).expect(
        "AOT artifacts missing or stale — run `make artifacts` before `cargo test`",
    )
}

fn rand_weights(rng: &mut Rng) -> Weights {
    let mut w = [0.0f32; DIM];
    for v in w.iter_mut() {
        *v = rng.f32() * 2.0 - 1.0;
    }
    Weights {
        w,
        b: rng.f32() - 0.5,
    }
}

fn rand_batch(rng: &mut Rng, rows: usize) -> Vec<f32> {
    (0..rows * DIM).map(|_| rng.f32() * 2.0 - 1.0).collect()
}

#[test]
fn score_parity_native_vs_pjrt() {
    let e = engine();
    let mut rng = Rng::new(42);
    for rows in [1usize, 7, 64, 256] {
        let wts = rand_weights(&mut rng);
        let x = rand_batch(&mut rng, rows);
        let pjrt = e.score(&wts.w, wts.b, &x).unwrap();
        let native = wts.score_batch(&x);
        assert_eq!(pjrt.len(), rows);
        for (i, (a, b)) in pjrt.iter().zip(&native).enumerate() {
            assert!(
                (a - b).abs() < 1e-5,
                "rows={rows} i={i}: pjrt={a} native={b}"
            );
        }
    }
}

#[test]
fn train_parity_native_vs_pjrt() {
    let e = engine();
    let mut rng = Rng::new(43);
    let mut wts_native = rand_weights(&mut rng);
    let wts0 = wts_native;
    let x = rand_batch(&mut rng, 256);
    let y: Vec<f32> = (0..256).map(|_| f32::from(rng.chance(0.5))).collect();
    let lr = 0.1f32;

    let native_loss = wts_native.train_step(&x, &y, lr);
    let (w_pjrt, b_pjrt, loss_pjrt) = e.train_step(&wts0.w, wts0.b, &x, &y, lr).unwrap();

    assert!(
        (native_loss - loss_pjrt).abs() < 1e-4,
        "loss: native={native_loss} pjrt={loss_pjrt}"
    );
    for i in 0..DIM {
        assert!(
            (wts_native.w[i] - w_pjrt[i]).abs() < 1e-5,
            "w[{i}]: native={} pjrt={}",
            wts_native.w[i],
            w_pjrt[i]
        );
    }
    assert!((wts_native.b - b_pjrt).abs() < 1e-5);
}

#[test]
fn multi_step_training_stays_in_lockstep() {
    // Run 10 alternating steps through both backends from the same start;
    // divergence would indicate accumulation error or a math mismatch.
    let e = engine();
    let mut rng = Rng::new(44);
    let mut native = rand_weights(&mut rng);
    let mut pjrt_w = native.w;
    let mut pjrt_b = native.b;
    for step in 0..10 {
        let x = rand_batch(&mut rng, 256);
        let y: Vec<f32> = (0..256).map(|_| f32::from(rng.chance(0.5))).collect();
        native.train_step(&x, &y, 0.05);
        let (w2, b2, _) = e.train_step(&pjrt_w, pjrt_b, &x, &y, 0.05).unwrap();
        pjrt_w = w2;
        pjrt_b = b2;
        for i in 0..DIM {
            assert!(
                (native.w[i] - pjrt_w[i]).abs() < 1e-4,
                "diverged at step {step}, w[{i}]"
            );
        }
    }
}

#[test]
fn bandit_update_parity() {
    let e = engine();
    let mut rng = Rng::new(45);
    let mut values = [0.0f32; 64];
    for v in values.iter_mut() {
        *v = rng.f32();
    }
    let out = e.bandit_update(&values, 13, 2.5, 0.25).unwrap();
    for (i, (o, v)) in out.iter().zip(&values).enumerate() {
        let expect = if i == 13 { v + 0.25 * (2.5 - v) } else { *v };
        assert!((o - expect).abs() < 1e-6, "slot {i}: {o} vs {expect}");
    }
}

#[test]
fn training_on_separable_data_converges_via_pjrt() {
    // Same convergence check as python/tests/test_kernel.py, but through
    // the Rust-side PJRT path — proving the full loop works from Rust.
    let e = engine();
    let mut rng = Rng::new(46);
    let mut true_w = [0.0f32; DIM];
    for v in true_w.iter_mut() {
        *v = rng.f32() * 2.0 - 1.0;
    }
    let x = rand_batch(&mut rng, 256);
    let y: Vec<f32> = x
        .chunks_exact(DIM)
        .map(|row| {
            let dot: f32 = row.iter().zip(&true_w).map(|(a, b)| a * b).sum();
            f32::from(dot > 0.0)
        })
        .collect();
    let mut w = [0.0f32; DIM];
    let mut b = 0.0f32;
    let mut first = None;
    let mut last = 0.0;
    for _ in 0..60 {
        let (w2, b2, loss) = e.train_step(&w, b, &x, &y, 0.5).unwrap();
        w = w2;
        b = b2;
        first.get_or_insert(loss);
        last = loss;
    }
    let first = first.unwrap();
    assert!(
        last < 0.4 * first,
        "PJRT training failed to converge: {first} -> {last}"
    );
}

#[test]
fn rejects_malformed_batches() {
    let e = engine();
    let w = [0.0f32; DIM];
    // Wrong row width.
    assert!(e.score(&w, 0.0, &[0.0; 17]).is_err());
    // Oversized batch.
    assert!(e.score(&w, 0.0, &vec![0.0; (256 + 1) * DIM]).is_err());
    // Short train batch.
    assert!(e
        .train_step(&w, 0.0, &[0.0; DIM], &[0.0], 0.1)
        .is_err());
    // Bandit slot out of range.
    assert!(e.bandit_update(&[0.0; 64], 64, 1.0, 0.1).is_err());
}
