//! Tiered-store integration tests (DESIGN.md §6): a committed legacy
//! JSONL store imports in place, resumes with 0 recomputed cells, and
//! reports byte-identically; a torn segment footer is quarantined (its
//! cells recompute) rather than silently dropped; and jsonl-format vs
//! tiered-format campaigns produce identical report bytes, before and
//! after compaction.

use slofetch::campaign::{self, report, CampaignSpec, ResultStore, StoreFormat};
use std::path::PathBuf;

/// The spec whose expanded keys the committed fixture holds.
fn spec() -> CampaignSpec {
    CampaignSpec {
        name: "legacy".into(),
        apps: vec!["crypto".into()],
        prefetchers: vec!["nl".into(), "eip256".into()],
        records: 2_000,
        seeds: vec![3],
        ml: vec![false],
        churn_scale: vec![1.0],
        traffic: vec!["none".into()],
        clusters: Vec::new(),
        policies: vec!["reactive".into()],
        sketch: Vec::new(),
    }
}

fn fixture() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/legacy_campaign.jsonl")
}

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("slofetch_store_itest").join(name);
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn markdowns(store: &ResultStore) -> Vec<String> {
    report::reports(store).iter().map(|t| t.markdown()).collect()
}

#[test]
fn legacy_fixture_imports_resumes_zero_and_reports_identically() {
    let dir = tmp_dir("legacy_import");
    let path = dir.join("results.jsonl");
    std::fs::copy(fixture(), &path).unwrap();

    // Reports straight off the legacy file (read-only load).
    let legacy = ResultStore::load(&path).unwrap();
    assert_eq!(legacy.len(), 4);
    let legacy_reports = markdowns(&legacy);
    drop(legacy);

    // A tiered open imports the file in place: the path becomes a store
    // directory with the old log as its WAL. Nothing is recomputed and
    // no report byte moves (PR 4/5/7 hash-compat guarantees).
    let mut store = ResultStore::open_format(&path, StoreFormat::Tiered).unwrap();
    assert!(path.is_dir(), "legacy file should have become a store directory");
    assert_eq!(store.len(), 4);
    assert_eq!(markdowns(&store), legacy_reports, "import changed report bytes");

    // Fold the imported WAL into a segment: reports now range-scan the
    // segment by kind tag and must still be byte-identical.
    store.flush().unwrap();
    assert_eq!(store.segment_count(), 1);
    assert_eq!(markdowns(&store), legacy_reports, "segment scan changed report bytes");

    // Resume: the matching spec recomputes nothing.
    let out = campaign::run_to_store(&spec(), 2, &mut store).unwrap();
    assert_eq!(out.computed, 0, "legacy import recomputed cells");
    assert_eq!(out.skipped, 2);
    assert_eq!(markdowns(&store), legacy_reports, "no-op resume changed report bytes");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn torn_segment_footer_quarantines_and_recomputes() {
    let dir = tmp_dir("torn");
    let path = dir.join("results.store");
    {
        let mut store = ResultStore::open_format(&path, StoreFormat::Tiered).unwrap();
        let out = campaign::run_to_store(&spec(), 2, &mut store).unwrap();
        assert_eq!(out.computed, 2);
        store.flush().unwrap();
        assert_eq!(store.segment_count(), 1);
    }
    // Tear the footer off the segment, as a crash mid-write would.
    let seg = std::fs::read_dir(&path)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .find(|p| p.extension().is_some_and(|x| x == "seg"))
        .unwrap();
    let len = std::fs::metadata(&seg).unwrap().len();
    std::fs::OpenOptions::new().write(true).open(&seg).unwrap().set_len(len - 37).unwrap();

    let mut store = ResultStore::open(&path).unwrap();
    assert_eq!(store.quarantined().len(), 1, "torn segment not quarantined");
    let q = store.quarantined()[0].clone();
    assert!(
        q.to_string_lossy().ends_with(".seg.quarantined"),
        "torn segment should be renamed for inspection, got {q:?}"
    );
    assert_eq!(store.segment_count(), 0);
    // Its cells read as absent and recompute...
    let out = campaign::run_to_store(&spec(), 2, &mut store).unwrap();
    assert_eq!(out.computed, 2, "quarantined cells must recompute");
    // ...while the damaged bytes stay on disk, never silently dropped.
    assert!(q.exists(), "quarantined segment file was deleted");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn jsonl_and_tiered_campaigns_report_identically() {
    let dir = tmp_dir("formats");
    let jp = dir.join("results.jsonl");
    let tp = dir.join("results.store");
    let mut js = ResultStore::open_format(&jp, StoreFormat::Jsonl).unwrap();
    campaign::run_to_store(&spec(), 1, &mut js).unwrap();
    let a = markdowns(&js);

    // Worst case for ordering: one segment per record, computed on a
    // different thread count.
    let mut ts = ResultStore::open_format(&tp, StoreFormat::Tiered).unwrap();
    ts.set_flush_threshold(1);
    campaign::run_to_store(&spec(), 4, &mut ts).unwrap();
    assert_eq!(ts.segment_count(), 2);
    assert_eq!(a, markdowns(&ts), "store format changed report bytes");

    // Compaction and a cold reopen change neither counts nor bytes.
    let stats = ts.compact().unwrap();
    assert_eq!(stats.segments_after, 1);
    assert_eq!(stats.records, 2);
    drop(ts);
    let ts = ResultStore::open(&tp).unwrap();
    assert_eq!(ts.len(), 2);
    assert_eq!(a, markdowns(&ts), "compaction changed report bytes");
    std::fs::remove_dir_all(&dir).ok();
}
