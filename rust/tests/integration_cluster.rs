//! Cluster-simulator integration tests (DESIGN.md §8/§9): (a) the
//! shipped `examples/cluster.json` spec runs ≥1M requests across a
//! fan-out DAG under ≥2 traffic shapes and the full autoscaler policy
//! suite, with output identical across `--threads` values and reruns,
//! (b) the degenerate linear-chain topology reproduces the `rpc`
//! figure's qualitative ordering (faster prefetcher ⇒ tighter P99), and
//! (c) the reactive control loop reduces P99 burn versus a static
//! config in a bursty scenario.

use slofetch::cluster::{self, engine, ClusterSpec, ResolvedTopology, RunParams, TrafficShape};
use slofetch::trace::{codec, gen};
use std::path::Path;
use std::sync::OnceLock;

fn example_spec() -> ClusterSpec {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/cluster.json");
    ClusterSpec::load(&path).expect("examples/cluster.json must load")
}

/// The shipped spec, run once at --threads 1 (shared across tests).
fn outcome() -> &'static cluster::ClusterOutcome {
    static OUT: OnceLock<cluster::ClusterOutcome> = OnceLock::new();
    OUT.get_or_init(|| cluster::run_spec(&example_spec(), 1).unwrap())
}

#[test]
fn example_spec_covers_the_acceptance_envelope() {
    let spec = example_spec();
    // Fan-out DAG: some service has >1 child, some has >1 parent.
    assert!(spec.topology.services.iter().any(|s| s.deps.len() > 1), "no fan-in");
    let fan_out = spec
        .topology
        .services
        .iter()
        .filter(|s| s.deps.iter().any(|d| d == "gateway"))
        .count();
    assert!(fan_out > 1, "no fan-out");
    assert!(spec.traffic.len() >= 2, "need ≥2 traffic shapes");
    let out = outcome();
    assert!(out.total_requests >= 1_000_000, "only {} requests", out.total_requests);
    assert!(out.total_events > out.total_requests * 5, "DAG events missing");
    assert_eq!(out.scenarios.len(), spec.scenario_count());
}

#[test]
fn output_is_identical_across_thread_counts_and_reruns() {
    // threads=4 is both a rerun and a different shard schedule; the
    // rendered report (every percentile, burn counter, and action) and
    // the raw P99 bits must match the threads=1 run exactly.
    let a = outcome();
    let b = cluster::run_spec(&example_spec(), 4).unwrap();
    assert_eq!(
        cluster::report(a).markdown(),
        cluster::report(&b).markdown(),
        "cluster output depends on --threads"
    );
    for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.traffic, y.traffic);
        assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}|{}", x.label, x.traffic);
        assert_eq!(x.events, y.events);
        assert_eq!(x.actions, y.actions);
    }
}

#[test]
fn faster_prefetcher_tightens_p99_in_the_example() {
    // The rpc figure's qualitative ordering, through the DAG engine at
    // fixed offered load: every service speeds up under ceip256, so the
    // stationary scenario's tail must tighten vs the nl baseline.
    let out = outcome();
    let p99 = |label: &str, traffic_prefix: &str| {
        out.scenarios
            .iter()
            .find(|s| s.label == label && s.traffic.starts_with(traffic_prefix))
            .unwrap_or_else(|| panic!("missing scenario {label}/{traffic_prefix}"))
            .p99_us
    };
    assert!(
        p99("ceip256", "poisson") < p99("nl", "poisson"),
        "ceip256 {} !< nl {}",
        p99("ceip256", "poisson"),
        p99("nl", "poisson")
    );
}

#[test]
fn control_loop_reduces_p99_burn_in_the_bursty_scenario() {
    let out = outcome();
    let find = |label: &str| {
        out.scenarios
            .iter()
            .find(|s| s.label == label && s.traffic.starts_with("burst"))
            .unwrap_or_else(|| panic!("missing burst scenario for {label}"))
    };
    let stat = find("nl");
    let adap = find("reactive");
    assert!(stat.violated_windows > 0, "burst scenario never burned — not a stress test");
    assert!(!adap.actions.is_empty(), "control loop never acted");
    assert!(
        adap.violated_windows < stat.violated_windows,
        "burn not reduced: adaptive {}/{} vs static {}/{}",
        adap.violated_windows,
        adap.windows,
        stat.violated_windows,
        stat.windows
    );
    assert!(
        adap.p99_us < stat.p99_us,
        "P99 not reduced: adaptive {} vs static {}",
        adap.p99_us,
        stat.p99_us
    );
}

#[test]
fn policy_suite_covers_every_policy_and_shape() {
    // The shipped spec lists all four autoscaler policies; each must
    // produce one scenario per traffic shape with sane results and
    // non-zero capacity accounting.
    let spec = example_spec();
    assert_eq!(spec.effective_policies().unwrap().len(), 4);
    let out = outcome();
    for prefix in ["reactive", "hysteresis", "predictive", "cost-aware"] {
        let rows: Vec<_> =
            out.scenarios.iter().filter(|s| s.label.starts_with(prefix)).collect();
        assert_eq!(rows.len(), 2, "policy '{prefix}' is missing a traffic shape");
        for s in rows {
            assert_eq!(s.requests, spec.requests, "{}: lost requests", s.label);
            assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us, "{}", s.label);
            assert!(s.replica_us > 0.0, "{}: no replica-seconds", s.label);
            assert!(s.duration_us > 0.0, "{}", s.label);
        }
    }
}

fn empirical_example_spec() -> ClusterSpec {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/cluster_empirical.json");
    ClusterSpec::load(&path).expect("examples/cluster_empirical.json must load")
}

#[test]
fn empirical_example_spec_is_thread_invariant_and_compares_models() {
    // The shipped trace-replayed spec (DESIGN.md §8 "Service-time
    // models"): byte-identical reports across thread counts and reruns,
    // and an analytic-vs-empirical comparison table with one row per
    // (config, shape).
    let mut spec = empirical_example_spec();
    spec.requests = 8_000; // keep the integration run quick
    assert!(spec.empirical());
    let a = cluster::run_spec(&spec, 1).unwrap();
    let b = cluster::run_spec(&spec, 4).unwrap();
    assert_eq!(a.scenarios.len(), spec.scenario_count());
    assert_eq!(
        cluster::report(&a).markdown(),
        cluster::report(&b).markdown(),
        "empirical cluster output depends on --threads"
    );
    let ma = cluster::model_report(&a).expect("model comparison missing");
    let mb = cluster::model_report(&b).expect("model comparison missing");
    assert_eq!(ma.markdown(), mb.markdown());
    assert_eq!(ma.rows.len(), spec.prefetchers.len() * spec.traffic.len());
    for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
        assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}|{}", x.label, x.traffic);
        assert_eq!(x.events, y.events);
    }
    // Every empirical twin is a real, distinct run of the same load.
    for emp in a.scenarios.iter().filter(|s| s.label.ends_with(cluster::EMPIRICAL_SUFFIX)) {
        let base = emp.label.trim_end_matches(cluster::EMPIRICAL_SUFFIX);
        let ana = a
            .scenarios
            .iter()
            .find(|s| s.label == base && s.traffic == emp.traffic)
            .expect("analytic twin missing");
        assert_eq!(emp.requests, ana.requests);
        assert!(emp.p50_us <= emp.p95_us && emp.p95_us <= emp.p99_us, "{}", emp.label);
        assert_ne!(emp.p99_us.to_bits(), ana.p99_us.to_bits(), "{} ran analytic", emp.label);
    }
}

#[test]
fn slft_file_replays_through_the_cluster_and_roundtrips() {
    // gen-trace artifact → .slft file → per-service replay: the codec
    // round-trip feeds prepare_spec, which must fit identical quantile
    // tables from the file as from the in-memory records, and reruns
    // must agree bit-for-bit.
    let dir = std::env::temp_dir().join("slofetch_cluster_slft");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("ws.slft");
    let app = gen::apps::app("websearch").unwrap();
    let (meta, records, _) = gen::generate(&app, 7, 20_000);
    codec::write_trace_file(&path, &meta, &records).unwrap();
    let (meta2, records2) = codec::read_trace_file(&path).unwrap();
    assert_eq!(meta2, meta);
    assert_eq!(records2, records, "codec round-trip degraded the replay input");

    let mut spec = empirical_example_spec();
    spec.requests = 4_000;
    spec.records = 8_000;
    spec.topology.services[0].trace = Some(path.to_string_lossy().into_owned());
    spec.validate().unwrap();
    let p1 = cluster::prepare_spec(&spec, 1).unwrap();
    let p2 = cluster::prepare_spec(&spec, 4).unwrap();
    for (a, b) in p1.policy_topo.services.iter().zip(&p2.policy_topo.services) {
        for (ca, cb) in a.candidates.iter().zip(&b.candidates) {
            let ta = ca.table.expect("file-backed candidate lost its table");
            let tb = cb.table.expect("file-backed candidate lost its table");
            assert_eq!(ta.fingerprint(), tb.fingerprint(), "tables differ across threads");
            assert_eq!(ca.mean_us.to_bits(), cb.mean_us.to_bits());
        }
    }
    // The file-backed service keys its measurement by the trace path,
    // so the spec reports one extra measurement source... unless the
    // other services already covered the app; either way the run is
    // deterministic end to end.
    let a = cluster::run_spec(&spec, 1).unwrap();
    let b = cluster::run_spec(&spec, 4).unwrap();
    assert_eq!(cluster::report(&a).markdown(), cluster::report(&b).markdown());
    std::fs::remove_file(&path).ok();
}

#[test]
fn degenerate_chain_matches_rpc_orderings() {
    // Synthetic IPCs, no trace simulation: the linear chain through the
    // cluster engine must show the tandem model's shape properties.
    let chain = |scale: f64| {
        ResolvedTopology::chain_from_ipcs(
            &[
                ("admission".into(), 2.0 * scale),
                ("featurestore".into(), 1.5 * scale),
                ("mlserve".into(), 2.5 * scale),
            ],
            25_000.0,
            0.35,
            2.5,
        )
    };
    let nl = chain(1.0);
    let lambda = nl.bottleneck_rate() * 0.65;
    let run = |topo: &ResolvedTopology| {
        engine::run(
            topo,
            &TrafficShape::Poisson { util: 1.0 },
            &RunParams { requests: 40_000, seed: 17, slo_us: 1e9, base_rate_per_us: lambda },
            None,
        )
        .unwrap()
    };
    let base = run(&nl);
    // Queueing tail above zero-load latency, ordered percentiles.
    assert!(base.p50_us <= base.p95_us && base.p95_us <= base.p99_us);
    assert!(base.p99_us > nl.zero_load_us());
    // 10% faster chain at the same absolute arrival rate: tighter tail
    // (the §XI compounding claim the rpc figure asserts).
    let fast = run(&chain(1.10));
    assert!(fast.p95_us < base.p95_us, "p95 {} !< {}", fast.p95_us, base.p95_us);
    assert!(fast.p99_us < base.p99_us, "p99 {} !< {}", fast.p99_us, base.p99_us);
    // Deterministic rerun.
    assert_eq!(run(&nl).p99_us.to_bits(), base.p99_us.to_bits());
}
