//! Cluster-simulator integration tests (DESIGN.md §8/§9): (a) the
//! shipped `examples/cluster.json` spec runs ≥1M requests across a
//! fan-out DAG under ≥2 traffic shapes and the full autoscaler policy
//! suite, with output identical across `--threads` values and reruns,
//! (b) the degenerate linear-chain topology reproduces the `rpc`
//! figure's qualitative ordering (faster prefetcher ⇒ tighter P99), and
//! (c) the reactive control loop reduces P99 burn versus a static
//! config in a bursty scenario.

use slofetch::cluster::{self, engine, ClusterSpec, ResolvedTopology, RunParams, TrafficShape};
use std::path::Path;
use std::sync::OnceLock;

fn example_spec() -> ClusterSpec {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/cluster.json");
    ClusterSpec::load(&path).expect("examples/cluster.json must load")
}

/// The shipped spec, run once at --threads 1 (shared across tests).
fn outcome() -> &'static cluster::ClusterOutcome {
    static OUT: OnceLock<cluster::ClusterOutcome> = OnceLock::new();
    OUT.get_or_init(|| cluster::run_spec(&example_spec(), 1).unwrap())
}

#[test]
fn example_spec_covers_the_acceptance_envelope() {
    let spec = example_spec();
    // Fan-out DAG: some service has >1 child, some has >1 parent.
    assert!(spec.topology.services.iter().any(|s| s.deps.len() > 1), "no fan-in");
    let fan_out = spec
        .topology
        .services
        .iter()
        .filter(|s| s.deps.iter().any(|d| d == "gateway"))
        .count();
    assert!(fan_out > 1, "no fan-out");
    assert!(spec.traffic.len() >= 2, "need ≥2 traffic shapes");
    let out = outcome();
    assert!(out.total_requests >= 1_000_000, "only {} requests", out.total_requests);
    assert!(out.total_events > out.total_requests * 5, "DAG events missing");
    assert_eq!(out.scenarios.len(), spec.scenario_count());
}

#[test]
fn output_is_identical_across_thread_counts_and_reruns() {
    // threads=4 is both a rerun and a different shard schedule; the
    // rendered report (every percentile, burn counter, and action) and
    // the raw P99 bits must match the threads=1 run exactly.
    let a = outcome();
    let b = cluster::run_spec(&example_spec(), 4).unwrap();
    assert_eq!(
        cluster::report(a).markdown(),
        cluster::report(&b).markdown(),
        "cluster output depends on --threads"
    );
    for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.traffic, y.traffic);
        assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}|{}", x.label, x.traffic);
        assert_eq!(x.events, y.events);
        assert_eq!(x.actions, y.actions);
    }
}

#[test]
fn faster_prefetcher_tightens_p99_in_the_example() {
    // The rpc figure's qualitative ordering, through the DAG engine at
    // fixed offered load: every service speeds up under ceip256, so the
    // stationary scenario's tail must tighten vs the nl baseline.
    let out = outcome();
    let p99 = |label: &str, traffic_prefix: &str| {
        out.scenarios
            .iter()
            .find(|s| s.label == label && s.traffic.starts_with(traffic_prefix))
            .unwrap_or_else(|| panic!("missing scenario {label}/{traffic_prefix}"))
            .p99_us
    };
    assert!(
        p99("ceip256", "poisson") < p99("nl", "poisson"),
        "ceip256 {} !< nl {}",
        p99("ceip256", "poisson"),
        p99("nl", "poisson")
    );
}

#[test]
fn control_loop_reduces_p99_burn_in_the_bursty_scenario() {
    let out = outcome();
    let find = |label: &str| {
        out.scenarios
            .iter()
            .find(|s| s.label == label && s.traffic.starts_with("burst"))
            .unwrap_or_else(|| panic!("missing burst scenario for {label}"))
    };
    let stat = find("nl");
    let adap = find("reactive");
    assert!(stat.violated_windows > 0, "burst scenario never burned — not a stress test");
    assert!(!adap.actions.is_empty(), "control loop never acted");
    assert!(
        adap.violated_windows < stat.violated_windows,
        "burn not reduced: adaptive {}/{} vs static {}/{}",
        adap.violated_windows,
        adap.windows,
        stat.violated_windows,
        stat.windows
    );
    assert!(
        adap.p99_us < stat.p99_us,
        "P99 not reduced: adaptive {} vs static {}",
        adap.p99_us,
        stat.p99_us
    );
}

#[test]
fn policy_suite_covers_every_policy_and_shape() {
    // The shipped spec lists all four autoscaler policies; each must
    // produce one scenario per traffic shape with sane results and
    // non-zero capacity accounting.
    let spec = example_spec();
    assert_eq!(spec.effective_policies().unwrap().len(), 4);
    let out = outcome();
    for prefix in ["reactive", "hysteresis", "predictive", "cost-aware"] {
        let rows: Vec<_> =
            out.scenarios.iter().filter(|s| s.label.starts_with(prefix)).collect();
        assert_eq!(rows.len(), 2, "policy '{prefix}' is missing a traffic shape");
        for s in rows {
            assert_eq!(s.requests, spec.requests, "{}: lost requests", s.label);
            assert!(s.p50_us <= s.p95_us && s.p95_us <= s.p99_us, "{}", s.label);
            assert!(s.replica_us > 0.0, "{}: no replica-seconds", s.label);
            assert!(s.duration_us > 0.0, "{}", s.label);
        }
    }
}

#[test]
fn degenerate_chain_matches_rpc_orderings() {
    // Synthetic IPCs, no trace simulation: the linear chain through the
    // cluster engine must show the tandem model's shape properties.
    let chain = |scale: f64| {
        ResolvedTopology::chain_from_ipcs(
            &[
                ("admission".into(), 2.0 * scale),
                ("featurestore".into(), 1.5 * scale),
                ("mlserve".into(), 2.5 * scale),
            ],
            25_000.0,
            0.35,
            2.5,
        )
    };
    let nl = chain(1.0);
    let lambda = nl.bottleneck_rate() * 0.65;
    let run = |topo: &ResolvedTopology| {
        engine::run(
            topo,
            &TrafficShape::Poisson { util: 1.0 },
            &RunParams { requests: 40_000, seed: 17, slo_us: 1e9, base_rate_per_us: lambda },
            None,
        )
    };
    let base = run(&nl);
    // Queueing tail above zero-load latency, ordered percentiles.
    assert!(base.p50_us <= base.p95_us && base.p95_us <= base.p99_us);
    assert!(base.p99_us > nl.zero_load_us());
    // 10% faster chain at the same absolute arrival rate: tighter tail
    // (the §XI compounding claim the rpc figure asserts).
    let fast = run(&chain(1.10));
    assert!(fast.p95_us < base.p95_us, "p95 {} !< {}", fast.p95_us, base.p95_us);
    assert!(fast.p99_us < base.p99_us, "p99 {} !< {}", fast.p99_us, base.p99_us);
    // Deterministic rerun.
    assert_eq!(run(&nl).p99_us.to_bits(), base.p99_us.to_bits());
}
