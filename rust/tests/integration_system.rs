//! Cross-module integration: trace → codec → simulator → controller →
//! deployment playbook → RPC layer, plus failure-injection cases.

use slofetch::config::{ControllerCfg, PrefetcherKind, SimConfig};
use slofetch::coordinator::deploy::{DeployStage, DeploymentManager};
use slofetch::coordinator::fleet::{run_fleet, FleetJob};
use slofetch::rpc::{self, QueueParams, ServiceChain};
use slofetch::sim::engine;
use slofetch::trace::gen::{self, apps};
use slofetch::trace::{codec, Record};

#[test]
fn trace_file_roundtrip_preserves_sim_results() {
    // Simulating a trace that went through the codec must give identical
    // results to the in-memory stream (bit-exact substrate).
    let spec = apps::app("serde").unwrap();
    let (meta, records, _) = gen::generate(&spec, 9, 60_000);
    let dir = std::env::temp_dir().join("slofetch_it");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("serde.slft");
    codec::write_trace_file(&path, &meta, &records).unwrap();
    let (meta2, records2) = codec::read_trace_file(&path).unwrap();
    assert_eq!(meta.app, meta2.app);
    assert_eq!(records, records2);
    let cfg = SimConfig {
        prefetcher: PrefetcherKind::Ceip { entries: 2048, window: 8, whole_window: true },
        ..Default::default()
    };
    let a = engine::run(&cfg, &records);
    let b = engine::run(&cfg, &records2);
    assert_eq!(a.stats.cycles, b.stats.cycles);
    assert_eq!(a.stats.pf_issued, b.stats.pf_issued);
    std::fs::remove_file(&path).ok();
}

#[test]
fn full_pipeline_trace_to_tail_latency() {
    // The end-to-end path every figure depends on: generate traces, run
    // the fleet over configs, feed IPCs into the queueing layer.
    let jobs: Vec<FleetJob> = ["admission", "featurestore-go", "mlserve"]
        .iter()
        .flat_map(|app| {
            [PrefetcherKind::NextLineOnly, PrefetcherKind::Cheip {
                vt_entries: 2048,
                window: 8,
                whole_window: true,
            }]
            .into_iter()
            .map(|kind| FleetJob {
                app: apps::app(app).unwrap(),
                cfg: SimConfig {
                    prefetcher: kind,
                    ..Default::default()
                },
                records: 120_000,
                trace_seed: 5,
            })
        })
        .collect();
    let cells = run_fleet(jobs, 4);
    assert_eq!(cells.len(), 6);
    let chain_for = |offset: usize| {
        ServiceChain::control_plane(
            &[
                ("admission".into(), cells[offset].result.ipc()),
                ("featurestore".into(), cells[2 + offset].result.ipc()),
                ("mlserve".into(), cells[4 + offset].result.ipc()),
            ],
            25_000.0,
            2.5,
        )
    };
    let nl_chain = chain_for(0);
    let pf_chain = chain_for(1);
    let lambda = nl_chain.bottleneck_rate() * 0.65;
    let run_chain = |chain: &ServiceChain| {
        rpc::simulate_chain(
            chain,
            &QueueParams {
                utilization: lambda / chain.bottleneck_rate(),
                requests: 15_000,
                seed: 2,
            },
        )
    };
    let nl = run_chain(&nl_chain);
    let pf = run_chain(&pf_chain);
    assert!(
        pf.p95_us < nl.p95_us,
        "CHEIP must narrow P95: {} !< {}",
        pf.p95_us,
        nl.p95_us
    );
}

#[test]
fn deployment_playbook_end_to_end() {
    let records = gen::generate_records(&apps::app("admission").unwrap(), 3, 200_000);
    let dm = DeploymentManager::new(
        SimConfig::default(),
        SimConfig {
            prefetcher: PrefetcherKind::Cheip { vt_entries: 2048, window: 8, whole_window: true },
            controller: Some(ControllerCfg {
                train_interval_cycles: 150_000,
                ..Default::default()
            }),
            ..Default::default()
        },
    );
    let out = dm.run(&records);
    assert_eq!(out.final_stage, DeployStage::Steady, "{:#?}", out.reports);
}

#[test]
fn budget_cap_bounds_issue_rate_end_to_end() {
    let records = gen::generate_records(&apps::app("websearch").unwrap(), 5, 150_000);
    let uncapped = engine::run(
        &SimConfig {
            prefetcher: PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true },
            controller: Some(ControllerCfg::default()),
            ..Default::default()
        },
        &records,
    );
    let capped = engine::run(
        &SimConfig {
            prefetcher: PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true },
            controller: Some(ControllerCfg {
                issue_budget_per_kcycle: 4,
                ..Default::default()
            }),
            ..Default::default()
        },
        &records,
    );
    assert!(
        capped.stats.pf_issued < uncapped.stats.pf_issued,
        "budget must bite: {} !< {}",
        capped.stats.pf_issued,
        uncapped.stats.pf_issued
    );
    // The cap maps to a bandwidth SLO: DRAM traffic must drop too.
    assert!(capped.stats.dram_bytes <= uncapped.stats.dram_bytes);
}

#[test]
fn shadow_mode_issues_nothing_but_logs_utility() {
    // §VI-A step 1: decisions are made and logged; no fills happen beyond
    // the always-on NL baseline.
    let records = gen::generate_records(&apps::app("websearch").unwrap(), 5, 150_000);
    let kind = PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true };
    let nl_only = engine::run(&SimConfig::default(), &records);
    let shadow = engine::run(
        &SimConfig {
            prefetcher: kind.clone(),
            controller: Some(ControllerCfg {
                shadow: true,
                ..Default::default()
            }),
            ..Default::default()
        },
        &records,
    );
    let live = engine::run(
        &SimConfig {
            prefetcher: kind,
            controller: Some(ControllerCfg::default()),
            ..Default::default()
        },
        &records,
    );
    assert!(shadow.stats.shadow_would_issue > 0, "nothing logged in shadow");
    assert!(shadow.stats.shadow_bytes > 0);
    // Shadow issues exactly what NL-only issues (the NL baseline).
    assert_eq!(shadow.stats.pf_issued, nl_only.stats.pf_issued);
    // And performs like the baseline, not like the live candidate.
    assert!((shadow.ipc() - nl_only.ipc()).abs() / nl_only.ipc() < 0.002);
    assert!(live.stats.pf_issued > shadow.stats.pf_issued);
}

#[test]
fn anomaly_guardrail_fires_on_churny_workloads() {
    // §VII: anomalous miss bursts must decay confidence. The churniest
    // app (canary flips every 250k records) must trigger at least once.
    let records =
        gen::generate_records(&apps::app("abscheduler-java").unwrap(), 13, 600_000);
    let r = engine::run(
        &SimConfig {
            prefetcher: PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true },
            ..Default::default()
        },
        &records,
    );
    assert!(r.stats.anomaly_resets > 0, "guardrail never fired");
    // Steady-state app: must NOT fire.
    let steady = gen::generate_records(&apps::app("crypto").unwrap(), 13, 300_000);
    let rs = engine::run(&SimConfig::default(), &steady);
    assert_eq!(rs.stats.anomaly_resets, 0, "false positive on steady state");
}

#[test]
fn corrupted_trace_fails_loudly_not_silently() {
    let spec = apps::app("crypto").unwrap();
    let (meta, records, _) = gen::generate(&spec, 1, 1_000);
    let mut buf = Vec::new();
    codec::write_trace(&mut buf, &meta, records.iter().copied(), 1_000).unwrap();
    // Flip the magic.
    buf[0] ^= 0xFF;
    assert!(codec::TraceReader::new(std::io::Cursor::new(buf)).is_err());
}

#[test]
fn empty_and_tiny_traces_are_safe() {
    let cfg = SimConfig::default();
    let r = engine::run(&cfg, &[]);
    assert_eq!(r.stats.instrs, 0);
    assert_eq!(r.ipc(), 0.0);
    let one = [Record::fetch(42, 16, 0)];
    let r = engine::run(&cfg, &one);
    assert_eq!(r.stats.instrs, 16);
    assert!(r.stats.cycles > 0.0);
}

#[test]
fn phase_churn_degrades_static_prefetcher_less_with_controller() {
    // Churn-heavy app: the controller should not *hurt* and usually trims
    // useless issues during phase flips.
    let records = gen::generate_records(&apps::app("abscheduler-java").unwrap(), 11, 200_000);
    let base = SimConfig {
        prefetcher: PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true },
        ..Default::default()
    };
    let plain = engine::run(&base, &records);
    let ml = engine::run(
        &SimConfig {
            controller: Some(ControllerCfg {
                train_interval_cycles: 100_000,
                ..Default::default()
            }),
            ..base
        },
        &records,
    );
    let ipc_ratio = ml.ipc() / plain.ipc();
    assert!(
        ipc_ratio > 0.97,
        "controller cost too high under churn: {ipc_ratio}"
    );
}
