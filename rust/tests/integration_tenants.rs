//! Multi-tenant co-location integration tests (DESIGN.md §10): (a) the
//! shipped `examples/cluster_tenants.json` spec runs solo + co-located
//! scenarios byte-identically across `--threads` values and reruns,
//! with a paired `cluster_tenants` table; (b) stripping the tenant
//! section (`slofetch cluster --tenants off`) reproduces the
//! single-tenant baseline bit-for-bit; (c) campaign stores written
//! before the tenant field reload and resume with 0 recomputed cells,
//! while editing a tenant binding invalidates exactly the tenant cells.

use slofetch::campaign::{self, CampaignSpec, ResultStore};
use slofetch::cluster::{self, ClusterSpec};
use std::path::Path;

fn tenant_spec() -> ClusterSpec {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/cluster_tenants.json");
    ClusterSpec::load(&path).expect("examples/cluster_tenants.json must load")
}

#[test]
fn tenant_example_is_thread_and_rerun_invariant() {
    let mut spec = tenant_spec();
    spec.requests = 5_000; // keep the integration run quick
    assert!(spec.tenancy());
    let a = cluster::run_spec(&spec, 1).unwrap();
    let b = cluster::run_spec(&spec, 8).unwrap();
    assert_eq!(a.scenarios.len(), spec.scenario_count());
    assert_eq!(
        cluster::report(&a).markdown(),
        cluster::report(&b).markdown(),
        "tenant cluster output depends on --threads"
    );
    for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
        assert_eq!(x.label, y.label);
        assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}", x.label);
        assert_eq!(x.events, y.events);
        assert_eq!(x.actions, y.actions);
        for (s, t) in x.tenants.iter().zip(&y.tenants) {
            assert_eq!(s.p99_us.to_bits(), t.p99_us.to_bits(), "{}@{}", x.label, s.name);
            assert_eq!(s.violated_windows, t.violated_windows);
            assert_eq!(s.final_ways, t.final_ways);
        }
    }
    // The paired table renders identically too, one row per
    // (config, tenant).
    let ta = cluster::tenant_report(&a).expect("cluster_tenants table missing");
    let tb = cluster::tenant_report(&b).expect("cluster_tenants table missing");
    assert_eq!(ta.markdown(), tb.markdown());
    assert_eq!(ta.rows.len(), spec.prefetchers.len() * spec.tenants.len());
    // Co-location can only widen the web tenant's tail: its solo twin
    // shares the arrival seed, and its co-runner both queues on the
    // shared gateway and overflows its way share.
    let coloc = a.scenarios.iter().find(|s| s.label == "nl@coloc").unwrap();
    let solo = a.scenarios.iter().find(|s| s.label == "nl@web").unwrap();
    let web = coloc.tenants.iter().find(|t| t.name == "web").unwrap();
    assert!(
        web.p99_us > solo.p99_us,
        "co-location tightened the tail?! coloc {} vs solo {}",
        web.p99_us,
        solo.p99_us
    );
    // Rerun at the same thread count: bit-equal.
    let c = cluster::run_spec(&spec, 1).unwrap();
    assert_eq!(cluster::report(&a).markdown(), cluster::report(&c).markdown());
}

#[test]
fn tenancy_off_is_byte_identical_to_the_single_tenant_baseline() {
    // `slofetch cluster --tenants off` clears the tenant section; the
    // result must be indistinguishable — spec, JSON, and output — from
    // a spec that never declared tenants at all.
    let mut off = tenant_spec();
    off.tenants.clear();
    off.requests = 4_000;
    let dump = off.to_json().dump();
    assert!(!dump.contains("tenants"), "tenant keys leaked into the baseline: {dump}");
    let reparsed = ClusterSpec::from_json(&off.to_json()).unwrap();
    assert_eq!(reparsed, off);
    let a = cluster::run_spec(&off, 1).unwrap();
    let b = cluster::run_spec(&reparsed, 4).unwrap();
    assert_eq!(cluster::report(&a).markdown(), cluster::report(&b).markdown());
    for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
        assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}", x.label);
        assert_eq!(x.events, y.events);
    }
    // No tenant table, no tenant stats on the baseline path.
    assert!(cluster::tenant_report(&a).is_none());
    assert!(a.scenarios.iter().all(|s| s.tenants.is_empty()));
}

fn tenant_campaign() -> CampaignSpec {
    let j = slofetch::util::json::Json::parse(
        r#"{
            "name": "pairings",
            "apps": ["crypto"],
            "prefetchers": ["nl"],
            "records": 8000,
            "seeds": [7],
            "clusters": [{
                "name": "shared",
                "services": [
                    {"name": "gw", "app": "admission"},
                    {"name": "be", "app": "serde", "deps": ["gw"]}
                ],
                "prefetchers": ["nl", "ceip256"],
                "traffic": ["poisson:0.6"],
                "requests": 2500,
                "records": 4000,
                "adaptive": false,
                "tenants": [
                    {"name": "web", "services": ["gw"], "traffic": "poisson:0.4",
                     "ways": 4, "demand_ways": 6},
                    {"name": "batch", "traffic": "poisson:0.3", "ways": 4,
                     "demand_ways": 5}
                ]
            }],
            "policies": []
        }"#,
    )
    .unwrap();
    CampaignSpec::from_json(&j).unwrap()
}

#[test]
fn pre_tenant_stores_resume_and_binding_edits_invalidate() {
    let dir = std::env::temp_dir().join("slofetch_tenant_resume");
    std::fs::create_dir_all(&dir).unwrap();

    // (a) A store written by a pre-tenancy build: single-tenant cluster
    // cells carry no "tenant" key. Such lines are exactly what this
    // build writes for tenant-less clusters, so write one, assert the
    // format, reload it, and rerun — 0 recomputed cells.
    let pre = dir.join("pre_tenant.jsonl");
    std::fs::remove_file(&pre).ok();
    let plain = CampaignSpec::from_json(
        &slofetch::util::json::Json::parse(
            r#"{
                "name": "plain",
                "apps": ["crypto"],
                "prefetchers": ["nl"],
                "records": 8000,
                "seeds": [7],
                "clusters": [{
                    "name": "edge",
                    "services": [{"name": "gw", "app": "admission"}],
                    "prefetchers": ["nl"],
                    "traffic": ["poisson:0.6"],
                    "requests": 2500,
                    "records": 4000,
                    "adaptive": false
                }],
                "policies": ["reactive"]
            }"#,
        )
        .unwrap(),
    )
    .unwrap();
    {
        let mut store = ResultStore::open(&pre).unwrap();
        campaign::run_to_store(&plain, 2, &mut store).unwrap();
    }
    let text = std::fs::read_to_string(&pre).unwrap();
    assert!(text.contains("\"kind\":\"cluster\""), "no cluster line written");
    assert!(!text.contains("\"tenant\""), "pre-tenancy line format drifted: {text}");
    let mut store = ResultStore::open(&pre).unwrap();
    let again = campaign::run_to_store(&plain, 2, &mut store).unwrap();
    assert_eq!(again.computed, 0, "pre-tenant store failed to resume");
    std::fs::remove_file(&pre).ok();

    // (b) Tenant-cell stores: resume is exact, and editing a tenant
    // binding invalidates the tenant cells (their keys hash the full
    // cluster spec, tenant section included) while the sim-cell matrix
    // is untouched.
    let spec = tenant_campaign();
    let mut store = ResultStore::in_memory();
    let first = campaign::run_to_store(&spec, 2, &mut store).unwrap();
    // 1 sim cell + 2 tenants × {solo, coloc}.
    assert_eq!(first.total, 5);
    assert_eq!(first.computed, 5);
    let resumed = campaign::run_to_store(&spec, 1, &mut store).unwrap();
    assert_eq!(resumed.computed, 0, "tenant cells recomputed on resume");
    let mut edited = spec.clone();
    edited.clusters[0].tenants[0].demand_ways = 4;
    let after_edit = campaign::run_to_store(&edited, 2, &mut store).unwrap();
    assert_eq!(
        after_edit.computed, 4,
        "a tenant-binding edit must invalidate exactly the 4 tenant cells"
    );
    assert_eq!(after_edit.skipped, 1, "the sim cell must survive the edit");
    // The report pairs strictly by content-hashed key: the fresh cells
    // pair with each other, the stale pre-edit cells group separately
    // and pair among themselves — never across the edit.
    let t = campaign::report::tenant_pairings(&store).expect("campaign_tenants missing");
    assert_eq!(t.rows.len(), 4, "stale + fresh pairings must both render");
    let paired = t.rows.iter().all(|r| r[4] != "-");
    assert!(paired, "a pairing crossed the spec edit: {:?}", t.rows);
}
