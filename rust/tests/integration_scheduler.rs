//! Scheduler-equivalence suite (DESIGN.md §13): the calendar queue must
//! be observationally identical to the binary-heap oracle on every
//! stream the engine can legally produce — monotone pushes (push time ≥
//! last popped time) with arbitrary duplicate timestamps. Property tests
//! drive random streams through both backends and demand identical pop
//! order bit-for-bit; directed tests hit the calendar's geometry edges
//! (all-equal timestamps, far-future ladder jumps, empty-bucket sweeps,
//! density-driven grow/shrink) and the spec-level knob end to end.

use std::path::Path;

use slofetch::cluster::sched::{event_key, CalendarQueue, HeapQueue, Scheduler};
use slofetch::cluster::{self, ClusterSpec};
use slofetch::util::prop;
use slofetch::util::rng::Rng;

/// Pop everything, returning `(t_bits, seq, item)` so float comparisons
/// are exact.
fn drain<S: Scheduler<usize>>(s: &mut S) -> Vec<(u64, u64, usize)> {
    let mut out = Vec::new();
    while let Some((t, seq, item)) = s.pop() {
        out.push((t.to_bits(), seq, item));
    }
    assert!(s.is_empty());
    out
}

/// Push one stream through both backends and assert identical pop order;
/// also checks the order against the contractual `event_key` sort.
fn assert_equivalent(ts: &[f64]) {
    let mut heap = HeapQueue::with_capacity(ts.len());
    let mut cal = CalendarQueue::with_capacity(ts.len());
    for (i, &t) in ts.iter().enumerate() {
        heap.push(t, i as u64, i);
        cal.push(t, i as u64, i);
    }
    assert_eq!(heap.len(), ts.len());
    assert_eq!(cal.len(), ts.len());
    let h = drain(&mut heap);
    let c = drain(&mut cal);
    assert_eq!(h, c, "backends disagree on pop order");
    let mut expect: Vec<(u64, u64, usize)> =
        ts.iter().enumerate().map(|(i, &t)| (t.to_bits(), i as u64, i)).collect();
    expect.sort_by_key(|&(bits, seq, _)| event_key(f64::from_bits(bits), seq));
    assert_eq!(h, expect, "pop order is not the (time, seq) sort");
}

/// Random monotone timestamp stream with deliberate collisions: ~1/4 of
/// events repeat the previous timestamp exactly and ~1/4 advance by a
/// small integer (colliding with later integer steps).
fn stream() -> impl FnMut(&mut Rng, usize) -> Vec<f64> {
    move |r, size| {
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(size * 8 + 1);
        for _ in 0..size * 8 + 1 {
            match r.below(4) {
                0 => {}
                1 => t += r.below(3) as f64,
                _ => t += r.f64() * 10.0,
            }
            out.push(t);
        }
        out
    }
}

#[test]
fn prop_push_all_drain_all_matches_heap() {
    prop::check_unit("scheduler equivalence (batch)", 40, stream(), |ts| {
        assert_equivalent(ts);
    });
}

#[test]
fn prop_interleaved_push_pop_matches_heap() {
    // The engine's actual shape: pops interleaved with pushes at or
    // after the last popped time (dt ≥ 0 service/arrival offsets).
    prop::check_unit("scheduler equivalence (interleaved)", 40, stream(), |ts| {
        let mut heap = HeapQueue::with_capacity(8);
        let mut cal = CalendarQueue::with_capacity(8);
        let mut r = Rng::new(0xC0FFEE ^ ts.len() as u64);
        let mut seq = 0u64;
        let mut now = 0.0f64;
        let mut it = ts.iter().copied().peekable();
        while it.peek().is_some() || !heap.is_empty() {
            for _ in 0..=r.below(3) {
                if let Some(dt) = it.next() {
                    // Stream values are monotone from 0, so `now + dt`
                    // respects the monotone-push contract by design.
                    heap.push(now + dt, seq, seq as usize);
                    cal.push(now + dt, seq, seq as usize);
                    seq += 1;
                }
            }
            for _ in 0..=r.below(2) {
                let h = heap.pop();
                let c = cal.pop();
                match (h, c) {
                    (None, None) => {}
                    (Some((ht, hs, hi)), Some((ct, cs, ci))) => {
                        assert_eq!((ht.to_bits(), hs, hi), (ct.to_bits(), cs, ci));
                        now = ht;
                    }
                    (h, c) => panic!("one backend emptied early: {h:?} vs {c:?}"),
                }
            }
        }
        assert_eq!(drain(&mut heap), drain(&mut cal));
    });
}

#[test]
fn all_equal_timestamps_pop_in_seq_order() {
    // Regression for the (time, seq) tie-break contract: simultaneous
    // events must drain in push order on every backend, so a scheduler
    // swap can never reorder same-timestamp work.
    let ts = vec![42.5; 1000];
    assert_equivalent(&ts);
    let mut cal = CalendarQueue::with_capacity(4);
    for (i, &t) in ts.iter().enumerate() {
        cal.push(t, i as u64, i);
    }
    for want in 0..ts.len() {
        let (t, seq, item) = cal.pop().unwrap();
        assert_eq!((t.to_bits(), seq, item), (42.5f64.to_bits(), want as u64, want));
    }
    assert!(cal.pop().is_none());
}

#[test]
fn far_future_jump_crosses_the_ladder() {
    // A handful of near events then far-future outliers: the outliers
    // land in the overflow ladder and the wheel must jump to them
    // (rather than sweeping ~1e12 empty buckets) once it drains.
    let mut ts = vec![0.0, 0.5, 1.0, 1.5, 2.0];
    ts.extend([1e9, 1e9, 1e9 + 1.0, 1e12, 1e12 + 0.25]);
    assert_equivalent(&ts);
}

#[test]
fn sparse_stream_sweeps_empty_buckets() {
    // Exponentially widening gaps: successive events keep landing far
    // past the current wheel window, exercising empty-bucket sweeps,
    // ladder migration, and repeated re-anchoring resizes.
    let mut ts = Vec::new();
    let mut t = 0.0f64;
    let mut gap = 1e-3f64;
    for _ in 0..64 {
        ts.push(t);
        t += gap;
        gap *= 1.7;
    }
    assert_equivalent(&ts);
}

#[test]
fn dense_then_sparse_forces_grow_and_shrink() {
    // Thousands of tightly packed events force the wheel to grow; after
    // the bulk drains, the stragglers trigger the shrink path on refill.
    let mut heap = HeapQueue::with_capacity(16);
    let mut cal = CalendarQueue::with_capacity(16);
    let mut r = Rng::new(9);
    let mut seq = 0u64;
    for _ in 0..8_000 {
        let t = r.f64() * 10.0;
        heap.push(t, seq, seq as usize);
        cal.push(t, seq, seq as usize);
        seq += 1;
    }
    let mut last = 0.0;
    for _ in 0..7_900 {
        let (ht, hs, hi) = heap.pop().unwrap();
        let (ct, cs, ci) = cal.pop().unwrap();
        assert_eq!((ht.to_bits(), hs, hi), (ct.to_bits(), cs, ci));
        last = ht;
    }
    for _ in 0..32 {
        let t = last + 100.0 + r.f64() * 5_000.0;
        heap.push(t, seq, seq as usize);
        cal.push(t, seq, seq as usize);
        seq += 1;
    }
    assert_eq!(drain(&mut heap), drain(&mut cal));
}

#[test]
fn spec_level_scheduler_knob_is_byte_identical() {
    // End to end through prepare_spec/run_spec: the shipped example spec
    // under `scheduler: heap` must reproduce the default calendar run's
    // report byte-stream exactly (the §8 determinism surface).
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/cluster.json");
    let mut spec = ClusterSpec::load(&path).expect("examples/cluster.json must load");
    spec.requests = 4_000;
    let cal = cluster::run_spec(&spec, 2).unwrap();
    spec.scheduler = "heap".into();
    spec.validate().unwrap();
    let heap = cluster::run_spec(&spec, 2).unwrap();
    assert_eq!(cluster::report(&cal).markdown(), cluster::report(&heap).markdown());
    assert_eq!(cal.total_events, heap.total_events);
    for (a, b) in cal.scenarios.iter().zip(&heap.scenarios) {
        assert_eq!(a.p99_us.to_bits(), b.p99_us.to_bits(), "{}", a.label);
        assert_eq!(a.peak_heap, b.peak_heap, "{}", a.label);
    }
}
