//! Observability integration tests (DESIGN.md §11): (a) obs-off runs
//! are byte-identical to the pre-obs baseline, (b) obs-on trace and
//! metrics artifacts are byte-identical across `--threads` values,
//! (c) span sampling is stable across reruns, and (d) the tenant path
//! records thread-invariant per-tenant controller internals.

use slofetch::cluster::{self, ClusterSpec};
use slofetch::obs::ObsCfg;
use slofetch::util::json::Json;
use std::path::Path;
use std::sync::OnceLock;

fn obs_spec() -> ClusterSpec {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/cluster_obs.json");
    let mut spec = ClusterSpec::load(&path).expect("examples/cluster_obs.json must load");
    spec.requests = 6_000; // keep the integration run quick
    spec
}

fn tenant_spec() -> ClusterSpec {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/cluster_tenants.json");
    let mut spec = ClusterSpec::load(&path).expect("examples/cluster_tenants.json must load");
    spec.requests = 3_000;
    spec
}

/// The shipped obs spec at --threads 1, obs on at 1-in-32 sampling
/// (shared across tests).
fn obs_outcome() -> &'static cluster::ClusterOutcome {
    static OUT: OnceLock<cluster::ClusterOutcome> = OnceLock::new();
    OUT.get_or_init(|| cluster::run_spec_obs(&obs_spec(), 1, &ObsCfg::on(5)).unwrap())
}

#[test]
fn obs_off_matches_the_baseline_byte_for_byte() {
    // run_spec (the pre-obs entry point) and run_spec_obs with obs
    // disabled must be the same computation: same report bytes, same
    // P99 bits, same event counts — and no observability payload.
    let base = cluster::run_spec(&obs_spec(), 1).unwrap();
    let off = cluster::run_spec_obs(&obs_spec(), 1, &ObsCfg::off()).unwrap();
    assert_eq!(
        cluster::report(&base).markdown(),
        cluster::report(&off).markdown(),
        "obs-off run diverged from the baseline"
    );
    for (x, y) in base.scenarios.iter().zip(&off.scenarios) {
        assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}|{}", x.label, x.traffic);
        assert_eq!(x.events, y.events);
        assert_eq!(x.peak_heap, y.peak_heap);
        assert!(y.obs.is_none(), "{}: obs-off run carried obs data", y.label);
    }
    assert!(cluster::critical_path_report(&off).is_none(), "obs-off report gained a table");

    // The obs-enabled run replays the identical event order — the
    // §8/§11 zero-perturbation contract.
    let on = obs_outcome();
    assert_eq!(cluster::report(&base).markdown(), cluster::report(on).markdown());
    for (x, y) in base.scenarios.iter().zip(&on.scenarios) {
        assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}|{}", x.label, x.traffic);
        assert_eq!(x.events, y.events);
        assert!(y.obs.is_some(), "{}: obs-on run lost its payload", y.label);
    }
}

#[test]
fn obs_artifacts_are_thread_invariant() {
    // threads=8 is both a rerun and a different shard schedule; every
    // exported artifact byte must match the threads=1 run.
    let a = obs_outcome();
    let b = cluster::run_spec_obs(&obs_spec(), 8, &ObsCfg::on(5)).unwrap();
    assert_eq!(cluster::report(a).markdown(), cluster::report(&b).markdown());
    let trace = cluster::trace_json(a).dump();
    assert_eq!(trace, cluster::trace_json(&b).dump(), "trace export depends on --threads");
    let metrics = cluster::metrics_jsonl(a);
    assert_eq!(metrics, cluster::metrics_jsonl(&b), "metrics export depends on --threads");
    let table = cluster::critical_path_report(a).expect("obs-on run must attribute spans");
    assert_eq!(
        table.markdown(),
        cluster::critical_path_report(&b).unwrap().markdown(),
        "critical-path table depends on --threads"
    );
    // Sanity: the artifacts carry real content in the documented shape.
    assert!(table.markdown().contains("gateway") && table.markdown().contains("render"));
    assert!(trace.contains("\"ph\":\"X\"") && trace.contains("process_name"));
    assert!(Json::parse(&trace).is_ok(), "trace is not valid JSON");
    assert!(!metrics.is_empty(), "no metrics snapshots recorded");
    for line in metrics.lines() {
        let j = Json::parse(line).expect("metrics line is not valid JSON");
        let text = j.dump();
        assert!(text.contains("\"scenario\"") && text.contains("\"t_us\""), "{text}");
    }
}

#[test]
fn sampling_is_stable_across_reruns() {
    let a = obs_outcome();
    let b = cluster::run_spec_obs(&obs_spec(), 1, &ObsCfg::on(5)).unwrap();
    for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
        let (dx, dy) = (x.obs.as_ref().unwrap(), y.obs.as_ref().unwrap());
        assert!(dx.sampled_requests > 0, "{}: nothing sampled", x.label);
        assert_eq!(dx.sampled_requests, dy.sampled_requests, "{}|{}", x.label, x.traffic);
        let reqs = |d: &slofetch::obs::ObsData| -> Vec<u64> {
            d.trace_spans.iter().map(|sp| sp.req).collect()
        };
        assert_eq!(reqs(dx), reqs(dy), "{}: sampled request set drifted", x.label);
    }
}

#[test]
fn tenant_path_obs_is_thread_invariant() {
    let spec = tenant_spec();
    let a = cluster::run_spec_obs(&spec, 1, &ObsCfg::on(4)).unwrap();
    let b = cluster::run_spec_obs(&spec, 4, &ObsCfg::on(4)).unwrap();
    assert_eq!(cluster::report(&a).markdown(), cluster::report(&b).markdown());
    assert_eq!(cluster::trace_json(&a).dump(), cluster::trace_json(&b).dump());
    let metrics = cluster::metrics_jsonl(&a);
    assert_eq!(metrics, cluster::metrics_jsonl(&b));
    // The adaptive tenant scenario snapshots per-tenant way shares and
    // burn rates at its window boundaries.
    assert!(
        metrics.contains("ways.") && metrics.contains("burn."),
        "tenant controller internals missing from the timeseries"
    );
}
