//! Campaign determinism + resume integration tests (DESIGN.md §6):
//! (a) the JSONL store is byte-identical at --threads 1 vs --threads 4,
//! (b) re-running against an existing store recomputes zero cells,
//! (c) the campaign runner and the serial path agree cell-for-cell.

use slofetch::campaign::{self, runner, CampaignSpec, ResultStore};
use slofetch::sim::engine;
use slofetch::trace::gen::{self, apps};
use std::path::PathBuf;

fn spec() -> CampaignSpec {
    CampaignSpec {
        name: "itest".into(),
        apps: vec!["crypto".into(), "serde".into()],
        prefetchers: vec!["nl".into(), "eip256".into(), "ceip256".into()],
        records: 25_000,
        seeds: vec![3],
        ml: vec![false],
        churn_scale: vec![1.0],
        traffic: vec!["none".into()],
        clusters: Vec::new(),
        policies: vec!["reactive".into()],
        sketch: Vec::new(),
    }
}

fn small_cluster() -> slofetch::cluster::ClusterSpec {
    let j = slofetch::util::json::Json::parse(
        r#"{
            "name": "edge",
            "services": [
                {"name": "gw", "app": "admission"},
                {"name": "be", "app": "serde", "deps": ["gw"]}
            ],
            "prefetchers": ["nl", "ceip256"],
            "traffic": ["poisson:0.6"],
            "requests": 6000,
            "records": 8000
        }"#,
    )
    .unwrap();
    slofetch::cluster::ClusterSpec::from_json(&j).unwrap()
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("slofetch_campaign_itest");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    std::fs::remove_file(&path).ok();
    path
}

#[test]
fn jsonl_is_byte_identical_across_thread_counts() {
    let spec = spec();
    let p1 = tmp("threads1.jsonl");
    let p4 = tmp("threads4.jsonl");
    {
        let mut s1 = ResultStore::open(&p1).unwrap();
        let out = campaign::run_to_store(&spec, 1, &mut s1).unwrap();
        assert_eq!(out.computed, 6);
    }
    {
        let mut s4 = ResultStore::open(&p4).unwrap();
        let out = campaign::run_to_store(&spec, 4, &mut s4).unwrap();
        assert_eq!(out.computed, 6);
    }
    let b1 = std::fs::read(&p1).unwrap();
    let b4 = std::fs::read(&p4).unwrap();
    assert!(!b1.is_empty());
    assert_eq!(b1, b4, "thread count changed the result bytes");
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p4).ok();
}

#[test]
fn rerun_against_existing_store_recomputes_nothing() {
    let spec = spec();
    let path = tmp("resume.jsonl");
    {
        let mut store = ResultStore::open(&path).unwrap();
        let first = campaign::run_to_store(&spec, 4, &mut store).unwrap();
        assert_eq!(first.computed, 6);
        assert_eq!(first.skipped, 0);
    }
    let bytes_after_first = std::fs::read(&path).unwrap();
    {
        // Fresh process simulation: reload the store from disk.
        let mut store = ResultStore::open(&path).unwrap();
        assert_eq!(store.len(), 6);
        let second = campaign::run_to_store(&spec, 2, &mut store).unwrap();
        assert_eq!(second.computed, 0, "resume recomputed cells");
        assert_eq!(second.skipped, 6);
    }
    // A pure resume must not touch the file either.
    assert_eq!(std::fs::read(&path).unwrap(), bytes_after_first);
    std::fs::remove_file(&path).ok();
}

#[test]
fn traffic_axis_store_is_byte_identical_across_thread_counts() {
    let spec = CampaignSpec {
        traffic: vec!["none".into(), "burst:0.5:3:50000:0.2".into()],
        records: 15_000,
        ..spec()
    };
    let p1 = tmp("traffic1.jsonl");
    let p4 = tmp("traffic4.jsonl");
    {
        let mut s = ResultStore::open(&p1).unwrap();
        let out = campaign::run_to_store(&spec, 1, &mut s).unwrap();
        assert_eq!(out.computed, 12);
    }
    {
        let mut s = ResultStore::open(&p4).unwrap();
        campaign::run_to_store(&spec, 4, &mut s).unwrap();
    }
    let b1 = std::fs::read(&p1).unwrap();
    assert_eq!(b1, std::fs::read(&p4).unwrap(), "traffic axis broke determinism");
    // Shaped cells carry tails; their IPC matches the `none` twin.
    let store = ResultStore::load(&p1).unwrap();
    let recs = store.records();
    let shaped: Vec<_> = recs.iter().filter(|r| r.tail.is_some()).collect();
    assert_eq!(shaped.len(), 6);
    for r in shaped {
        let base_key = r.key.split("|t").next().unwrap();
        let twin = recs.iter().find(|x| x.key == base_key).unwrap();
        assert_eq!(r.ipc.to_bits(), twin.ipc.to_bits(), "{}", r.key);
    }
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p4).ok();
}

#[test]
fn cluster_axis_store_is_byte_identical_and_resumes_from_old_stores() {
    let base = spec();
    let extended = CampaignSpec {
        clusters: vec![small_cluster()],
        policies: vec!["reactive".into(), "hysteresis".into(), "cost-aware:262144".into()],
        ..base.clone()
    };
    let p1 = tmp("cluster1.jsonl");
    let p4 = tmp("cluster4.jsonl");
    {
        let mut s = ResultStore::open(&p1).unwrap();
        let out = campaign::run_to_store(&extended, 1, &mut s).unwrap();
        // 6 sim cells + 3 policies × 1 shape.
        assert_eq!(out.computed, 9);
    }
    {
        let mut s = ResultStore::open(&p4).unwrap();
        campaign::run_to_store(&extended, 4, &mut s).unwrap();
    }
    let b1 = std::fs::read(&p1).unwrap();
    assert_eq!(b1, std::fs::read(&p4).unwrap(), "cluster axis broke determinism");

    // Rerun against the store: zero recomputed cells, file untouched.
    {
        let mut s = ResultStore::open(&p1).unwrap();
        assert_eq!(s.cluster_records().len(), 3);
        let again = campaign::run_to_store(&extended, 2, &mut s).unwrap();
        assert_eq!(again.computed, 0, "resume recomputed cells");
        assert_eq!(again.skipped, 9);
    }
    assert_eq!(std::fs::read(&p1).unwrap(), b1, "pure resume rewrote the store");

    // A pre-cluster store resumes too: its sim cells are skipped and
    // only the new cluster cells compute.
    let pold = tmp("precluster.jsonl");
    {
        let mut s = ResultStore::open(&pold).unwrap();
        assert_eq!(campaign::run_to_store(&base, 2, &mut s).unwrap().computed, 6);
    }
    {
        let mut s = ResultStore::open(&pold).unwrap();
        let out = campaign::run_to_store(&extended, 2, &mut s).unwrap();
        assert_eq!(out.computed, 3, "only cluster cells should compute");
        assert_eq!(out.skipped, 6);
        assert_eq!(s.cluster_records().len(), 3);
    }
    std::fs::remove_file(&p1).ok();
    std::fs::remove_file(&p4).ok();
    std::fs::remove_file(&pold).ok();
}

#[test]
fn empirical_slft_cluster_campaign_resumes_while_trace_unchanged() {
    // Trace-replayed cluster cells (DESIGN.md §8 "Service-time models"):
    // the cell key hashes the .slft file *content*, so a rerun with the
    // trace unchanged recomputes 0 cells, and rewriting the trace in
    // place invalidates them.
    let trace_path = tmp("replay.slft");
    let app = apps::app("serde").unwrap();
    let (meta, records, _) = gen::generate(&app, 11, 12_000);
    slofetch::trace::codec::write_trace_file(&trace_path, &meta, &records).unwrap();

    let mut cluster = small_cluster();
    cluster.service_times = "empirical".into();
    cluster.topology.services[1].trace = Some(trace_path.to_string_lossy().into_owned());
    let spec = CampaignSpec {
        clusters: vec![cluster],
        policies: vec!["reactive".into()],
        ..spec()
    };
    let path = tmp("empirical.jsonl");
    {
        let mut store = ResultStore::open(&path).unwrap();
        let out = campaign::run_to_store(&spec, 2, &mut store).unwrap();
        assert_eq!(out.computed, 7); // 6 sim cells + 1 cluster cell
        assert_eq!(store.cluster_records()[0].service_times, "empirical");
    }
    let bytes = std::fs::read(&path).unwrap();
    {
        // Unchanged trace content → full resume, file untouched.
        let mut store = ResultStore::open(&path).unwrap();
        let again = campaign::run_to_store(&spec, 4, &mut store).unwrap();
        assert_eq!(again.computed, 0, "resume recomputed empirical cluster cells");
        assert_eq!(again.skipped, 7);
    }
    assert_eq!(std::fs::read(&path).unwrap(), bytes, "pure resume rewrote the store");
    {
        // Rewrite the trace (same path, different content): only the
        // cluster cell recomputes, under a new content-hashed key.
        let (meta2, records2, _) = gen::generate(&app, 12, 12_000);
        slofetch::trace::codec::write_trace_file(&trace_path, &meta2, &records2).unwrap();
        let mut store = ResultStore::open(&path).unwrap();
        let out = campaign::run_to_store(&spec, 2, &mut store).unwrap();
        assert_eq!(out.computed, 1, "trace edit must invalidate exactly the cluster cell");
        assert_eq!(store.cluster_records().len(), 2);
    }
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&trace_path).ok();
}

#[test]
fn store_lines_match_direct_engine_runs() {
    // One cell cross-checked against a hand-built serial run.
    let spec = spec();
    let mut store = ResultStore::in_memory();
    campaign::run_to_store(&spec, 4, &mut store).unwrap();
    let cells = spec.expand().unwrap();
    let target = cells.iter().find(|c| c.key.starts_with("serde|ceip256|")).unwrap();
    let records =
        gen::generate_records(&apps::app("serde").unwrap(), target.cell.trace_seed, spec.records);
    let direct = engine::run(&target.cell.cfg, &records);
    let recs = store.records();
    let stored = recs.iter().find(|r| r.key == target.key).expect("cell missing from store");
    assert_eq!(stored.ipc, direct.ipc());
    assert_eq!(stored.pf_issued, direct.stats.pf_issued);
    assert_eq!(stored.metadata_bytes, direct.metadata_bytes);
}

#[test]
fn runner_matches_figures_serial_semantics() {
    // The figure harness routes through the campaign runner; a serial
    // run of the same cells must agree exactly.
    let cells: Vec<runner::Cell> = spec()
        .expand()
        .unwrap()
        .into_iter()
        .map(|c| c.cell)
        .collect();
    let parallel = runner::run_cells(&cells, 4);
    let serial = runner::run_cells(&cells, 1);
    for (a, b) in parallel.iter().zip(&serial) {
        assert_eq!(a.stats.cycles, b.stats.cycles);
        assert_eq!(a.stats.pf_issued, b.stats.pf_issued);
        assert_eq!(a.stats.instrs, b.stats.instrs);
    }
}
