//! Sketch-telemetry integration tests (DESIGN.md §12): (a) the
//! `exact` knob leaves the cluster pipeline byte-identical to the
//! pre-sketch baseline and carries no fleet payload, (b) sketch-mode
//! fleet artifacts (tables + metrics JSONL) are byte-identical across
//! `--threads` values, and (c) compare mode perturbs nothing while
//! tallying the exact-vs-sketch shadow.

use slofetch::cluster::{self, ClusterSpec};
use slofetch::util::json::Json;
use std::path::Path;
use std::sync::OnceLock;

fn base_spec() -> ClusterSpec {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/cluster_obs.json");
    let mut spec = ClusterSpec::load(&path).expect("examples/cluster_obs.json must load");
    spec.requests = 4_000; // keep the integration run quick
    spec
}

fn sketch_spec() -> ClusterSpec {
    let mut spec = base_spec();
    spec.telemetry = "sketch:w128d4p10k8".into();
    spec
}

/// The shipped obs spec under sketch telemetry at --threads 1 (shared
/// across tests).
fn sketch_outcome() -> &'static cluster::ClusterOutcome {
    static OUT: OnceLock<cluster::ClusterOutcome> = OnceLock::new();
    OUT.get_or_init(|| cluster::run_spec(&sketch_spec(), 1).unwrap())
}

#[test]
fn sketch_telemetry_leaves_simulation_results_untouched() {
    // The exact knob (the default) is the pre-sketch computation: same
    // report bytes, no fleet payload, no fleet tables.
    let base = cluster::run_spec(&base_spec(), 1).unwrap();
    assert!(base.fleet.is_none(), "exact knob must not allocate sketches");
    assert!(cluster::fleet_report(&base).is_none(), "exact run gained a fleet table");
    assert!(cluster::fleet_topk_report(&base).is_none());

    // Sketch mode only *observes*: every scenario result is bit-equal.
    let on = sketch_outcome();
    assert_eq!(
        cluster::report(&base).markdown(),
        cluster::report(on).markdown(),
        "sketch telemetry perturbed the cluster report"
    );
    for (x, y) in base.scenarios.iter().zip(&on.scenarios) {
        assert_eq!(x.p99_us.to_bits(), y.p99_us.to_bits(), "{}|{}", x.label, x.traffic);
        assert_eq!(x.events, y.events);
    }
    let fleet = on.fleet.as_ref().expect("sketch run lost its fleet payload");
    assert_eq!(fleet.cells.len(), on.ipc_cells, "one sketch per measurement cell");
    let per_cell: u64 = fleet.cells.iter().map(|(_, _, t)| t.issued.total()).sum();
    assert_eq!(fleet.merged.issued.total(), per_cell, "merge must preserve totals");
}

#[test]
fn fleet_artifacts_are_thread_invariant() {
    // threads=8 reshards the measurement cells; every fleet artifact
    // byte must match the threads=1 run.
    let a = sketch_outcome();
    let b = cluster::run_spec(&sketch_spec(), 8).unwrap();
    assert_eq!(cluster::report(a).markdown(), cluster::report(&b).markdown());
    let table = cluster::fleet_report(a).expect("sketch run must render the fleet table");
    assert_eq!(
        table.markdown(),
        cluster::fleet_report(&b).unwrap().markdown(),
        "fleet table depends on --threads"
    );
    let topk = cluster::fleet_topk_report(a).expect("sketch run must render hot contexts");
    assert_eq!(topk.markdown(), cluster::fleet_topk_report(&b).unwrap().markdown());
    let metrics = cluster::metrics_jsonl(a);
    assert_eq!(metrics, cluster::metrics_jsonl(&b), "fleet JSONL depends on --threads");
    // Sanity: the JSONL carries one line per cell plus the merged
    // summary, each valid JSON in the documented shape.
    let fleet_lines: Vec<&str> =
        metrics.lines().filter(|l| l.contains("\"scenario\":\"fleet\"")).collect();
    assert_eq!(fleet_lines.len(), a.fleet.as_ref().unwrap().cells.len() + 1);
    for line in &fleet_lines {
        let j = Json::parse(line).expect("fleet metrics line is not valid JSON");
        let text = j.dump();
        assert!(text.contains("\"contexts_est\"") && text.contains("\"cell\""), "{text}");
    }
}

#[test]
fn compare_mode_is_a_pure_shadow() {
    // Compare mode runs the exact path for real and the sketch path as
    // a shadow — results stay bit-equal to the baseline while the
    // telemetry gains the exact-side tallies.
    let base = cluster::run_spec(&base_spec(), 1).unwrap();
    let mut spec = base_spec();
    spec.telemetry = "compare:w128d4p10k8".into();
    let a = cluster::run_spec(&spec, 1).unwrap();
    let b = cluster::run_spec(&spec, 4).unwrap();
    assert_eq!(
        cluster::report(&base).markdown(),
        cluster::report(&a).markdown(),
        "compare mode perturbed the cluster report"
    );
    assert_eq!(
        cluster::fleet_report(&a).unwrap().markdown(),
        cluster::fleet_report(&b).unwrap().markdown(),
        "compare-mode fleet table depends on --threads"
    );
    assert_eq!(cluster::metrics_jsonl(&a), cluster::metrics_jsonl(&b));
    let fleet = a.fleet.as_ref().expect("compare run lost its fleet payload");
    for (src, pf, t) in &fleet.cells {
        let bytes = t.exact_counter_bytes().unwrap_or_else(|| {
            panic!("{src}|{pf}: compare-mode cell lost its exact shadow")
        });
        assert_eq!(bytes, t.exact_srcs.len() as u64 * 24, "{src}|{pf}");
    }
}
