//! Cross-module property suite: invariants that must hold for arbitrary
//! generated inputs (coordinator routing/batching/state per the project
//! testing bar, plus prefetcher/codec laws at system level).

use slofetch::config::{PrefetcherKind, SimConfig};
use slofetch::prefetch::centry::{CEntry, Mark};
use slofetch::sim::engine;
use slofetch::trace::{codec, Kind, Record, TraceMeta};
use slofetch::util::prop;
use slofetch::util::rng::Rng;

/// Random-but-clustered record stream (what the generator would emit).
fn record_stream() -> impl FnMut(&mut Rng, usize) -> Vec<Record> {
    move |r, size| {
        let mut out = Vec::with_capacity(size * 4);
        let mut line = r.range(0x40_0000, 0x41_0000);
        for _ in 0..size * 4 {
            match r.below(10) {
                0 => line = r.range(0x40_0000, 0x41_0000),
                1 => {
                    out.push(Record::load(r.range(0x100_0000, 0x101_0000), 0));
                    continue;
                }
                _ => line += 1,
            }
            out.push(Record::fetch(line, 1 + r.below(16) as u8, r.below(4) as u8));
        }
        out
    }
}

#[test]
fn prop_engine_accounting_identities() {
    prop::check_unit(
        "engine accounting identities",
        25,
        record_stream(),
        |records| {
            for kind in [
                PrefetcherKind::NextLineOnly,
                PrefetcherKind::Eip { entries: 512 },
                PrefetcherKind::Ceip { entries: 512, window: 8, whole_window: true },
                PrefetcherKind::Cheip { vt_entries: 2048, window: 8, whole_window: true },
            ] {
                let cfg = SimConfig {
                    prefetcher: kind,
                    ..Default::default()
                };
                let r = engine::run(&cfg, records);
                let s = &r.stats;
                // Identity: every fetch is a hit, covered miss, or miss.
                assert!(s.pf_timely + s.pf_late + s.l1i_demand_misses <= s.l1i_accesses);
                // Useful prefetches cannot exceed issued.
                assert!(s.pf_timely + s.pf_late <= s.pf_issued);
                // Useless evictions cannot exceed issued.
                assert!(s.pf_useless <= s.pf_issued);
                // Instructions accumulate exactly.
                let expect: u64 = records
                    .iter()
                    .filter(|r| r.kind == Kind::Fetch)
                    .map(|r| r.instrs as u64)
                    .sum();
                assert_eq!(s.instrs, expect);
                // Cycle accounting closes.
                assert!((s.topdown.total() - s.cycles).abs() <= 1.0 + s.cycles * 1e-9);
                // Rates in range.
                for v in [s.accuracy(), s.coverage(), s.timeliness()] {
                    assert!((0.0..=1.0).contains(&v));
                }
            }
        },
    );
}

#[test]
fn prop_codec_total_roundtrip() {
    prop::check_unit("codec roundtrip (system)", 30, record_stream(), |records| {
        let meta = TraceMeta {
            app: "prop".into(),
            seed: 0,
            line_bytes: 64,
            records: records.len() as u64,
        };
        let mut buf = Vec::new();
        codec::write_trace(&mut buf, &meta, records.iter().copied(), records.len() as u64)
            .unwrap();
        let back: Vec<Record> = codec::TraceReader::new(std::io::Cursor::new(buf))
            .unwrap()
            .map(|x| x.unwrap())
            .collect();
        assert_eq!(&back, records);
    });
}

#[test]
fn prop_centry_mark_laws() {
    // For any source/destination sequence in one 20-bit region:
    // (1) pack/unpack is lossless, (2) the creating mark is never silently
    // lost when it's the only mark, (3) density ∈ [1/W, 1] when any mark
    // exists.
    prop::check_unit(
        "centry mark laws",
        80,
        |r: &mut Rng, size| {
            let src = 0x0040_0000u64 | r.below(1 << 20);
            let dsts: Vec<u64> = (0..size.max(1))
                .map(|_| (src >> 20 << 20) | r.below(1 << 20))
                .collect();
            (src, dsts)
        },
        |(src, dsts)| {
            let mut e = CEntry::new(8, dsts[0]);
            assert_eq!(e.marked(), 1);
            for &d in &dsts[1..] {
                let m = e.mark(*src, d);
                assert!(!matches!(m, Mark::TooFar), "same-region dst rejected");
                assert!(e.marked() >= 1, "entry lost all marks");
                assert!(e.density() > 0.0 && e.density() <= 1.0);
                let packed = e.pack();
                assert_eq!(CEntry::unpack(packed, 8), e);
            }
        },
    );
}

#[test]
fn prop_deterministic_simulation() {
    prop::check_unit(
        "simulation determinism",
        10,
        record_stream(),
        |records| {
            let cfg = SimConfig {
                prefetcher: PrefetcherKind::Ceip { entries: 1024, window: 8, whole_window: true },
                controller: Some(Default::default()),
                ..Default::default()
            };
            let a = engine::run(&cfg, records);
            let b = engine::run(&cfg, records);
            assert_eq!(a.stats.cycles, b.stats.cycles);
            assert_eq!(a.stats.pf_issued, b.stats.pf_issued);
            assert_eq!(a.stats.pf_skipped, b.stats.pf_skipped);
        },
    );
}
