//! Quickstart — the end-to-end driver (EXPERIMENTS.md "End-to-end run").
//!
//! Exercises every layer of the system on a real small workload:
//!   1. generate the 11 microservice traces (the Fig 2 service mix),
//!   2. run the full prefetcher matrix through the fleet coordinator,
//!   3. gate CEIP through the online ML controller with training steps
//!      executed via the AOT JAX/Pallas artifacts on PJRT (when present;
//!      falls back to the bit-identical native mirror otherwise),
//!   4. report the paper's headline numbers (speedup, MPKI, accuracy,
//!      metadata budget) and the control-plane P95/P99.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use slofetch::config::{ControllerCfg, PrefetcherKind, SimConfig};
use slofetch::figures::{self, FigureCtx, Matrix};
use slofetch::ml::controller::{Backend, OnlineController};
use slofetch::runtime::PjrtEngine;
use slofetch::sim::engine::Engine;
use slofetch::trace::gen::{apps, generate_records};

fn main() -> anyhow::Result<()> {
    let records_per_app = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300_000u64);

    println!("== SLOFetch quickstart ==");
    println!("1) generating 11 app traces x {records_per_app} records and");
    println!("   running the {} -config matrix on the fleet driver...", figures::standard_configs().len());
    let m = Matrix::compute(FigureCtx {
        records_per_app,
        out_dir: None,
        ..Default::default()
    });

    println!("\n{}", figures::fig9(&m).markdown());
    println!("{}", figures::summary(&m).markdown());

    // --- Controller through the real PJRT path on one app.
    println!("2) online ML controller with AOT/PJRT training (websearch, CEIP-256):");
    let spec = apps::app("websearch").unwrap();
    let records = generate_records(&spec, 7, records_per_app);
    let cfg = SimConfig {
        prefetcher: PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true },
        controller: Some(ControllerCfg {
            train_interval_cycles: 500_000,
            ..Default::default()
        }),
        ..Default::default()
    };
    let mut engine = Engine::new(cfg.clone(), &records);
    match PjrtEngine::load_default() {
        Ok(pjrt) => {
            println!("   pjrt platform: {}", pjrt.platform());
            engine = engine.with_controller(OnlineController::with_backend(
                cfg.controller.clone().unwrap(),
                7,
                Backend::Pjrt(pjrt),
            ));
        }
        Err(e) => {
            println!("   (artifacts not found — native mirror backend: {e})");
        }
    }
    let r = engine.run();
    println!(
        "   ipc={:.4} mpki={:.2} accuracy={:.3} issued={} skipped={} trains={}",
        r.ipc(),
        r.stats.mpki(),
        r.stats.accuracy(),
        r.stats.pf_issued,
        r.stats.pf_skipped,
        r.controller.map(|c| c.trains).unwrap_or(0),
    );

    println!("\n3) control-plane RPC tails:\n");
    println!("{}", figures::rpc_tails(&m).markdown());
    println!("quickstart done.");
    Ok(())
}
