//! Tail-latency deep dive: sweep offered load and show how the prefetcher
//! families shift the P95/P99 latency-vs-utilization curve of a
//! control-plane RPC chain (paper §I, §XI).
//!
//! Run: `cargo run --release --example tail_latency`

use slofetch::config::{PrefetcherKind, SimConfig};
use slofetch::rpc::{self, QueueParams, ServiceChain};
use slofetch::sim::engine;
use slofetch::trace::gen::{apps, generate_records};

fn ipc_for(app: &str, kind: &PrefetcherKind, records: u64) -> f64 {
    let spec = apps::app(app).unwrap();
    let recs = generate_records(&spec, 7, records);
    engine::run(
        &SimConfig {
            prefetcher: kind.clone(),
            ..Default::default()
        },
        &recs,
    )
    .ipc()
}

fn main() {
    let records = 250_000u64;
    let chain_apps = ["admission", "featurestore-go", "mlserve"];
    let configs: Vec<(&str, PrefetcherKind)> = vec![
        ("nl", PrefetcherKind::NextLineOnly),
        ("ceip256", PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true }),
        ("cheip2k", PrefetcherKind::Cheip { vt_entries: 2048, window: 8, whole_window: true }),
    ];

    println!("measuring per-node IPC ({} records/app)...", records);
    let mut chains = Vec::new();
    for (name, kind) in &configs {
        let ipcs: Vec<(String, f64)> = chain_apps
            .iter()
            .map(|a| (a.to_string(), ipc_for(a, kind, records)))
            .collect();
        println!(
            "  {name:8} ipcs: {}",
            ipcs.iter()
                .map(|(a, i)| format!("{a}={i:.3}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
        chains.push((name, ServiceChain::control_plane(&ipcs, 25_000.0, 2.5)));
    }

    // Fixed absolute arrival rate (NL bottleneck at each sweep point).
    let nl_rate = chains[0].1.bottleneck_rate();
    println!("\n{:>6} | {:>22} | {:>22} | {:>22}", "load", "nl P95/P99", "ceip256 P95/P99", "cheip2k P95/P99");
    println!("{}", "-".repeat(84));
    for util in [0.3, 0.5, 0.65, 0.8, 0.9] {
        let lambda = nl_rate * util;
        let mut cells = Vec::new();
        for (_, chain) in &chains {
            let r = rpc::simulate_chain(
                chain,
                &QueueParams {
                    utilization: lambda / chain.bottleneck_rate(),
                    requests: 30_000,
                    seed: 4,
                },
            );
            cells.push(format!("{:8.1} / {:8.1}", r.p95_us, r.p99_us));
        }
        println!(
            "{:>5.0}% | {} | {} | {}",
            util * 100.0,
            cells[0],
            cells[1],
            cells[2]
        );
    }
    println!("\n(µs; lower is better — prefetching buys the most at high load,");
    println!(" which is exactly the paper's 'higher utilization without violating");
    println!(" tail targets' claim, §I)");
}
