//! Deployment playbook walkthrough (paper §VI-A): shadow → guarded canary
//! → ramp for CHEIP on the admission service, including a deliberately
//! poisoned candidate that must be rolled back by the canary gate.
//!
//! Run: `cargo run --release --example deployment_playbook`

use slofetch::config::{ControllerCfg, PrefetcherKind, SimConfig};
use slofetch::coordinator::deploy::{DeployStage, DeploymentManager, Gates};
use slofetch::trace::gen::{apps, generate_records};

fn main() {
    let records = generate_records(&apps::app("admission").unwrap(), 3, 400_000);
    let control = SimConfig::default();

    println!("== playbook run 1: healthy candidate (CHEIP-2K + ML controller) ==");
    let healthy = SimConfig {
        prefetcher: PrefetcherKind::Cheip { vt_entries: 2048, window: 8, whole_window: true },
        controller: Some(ControllerCfg {
            train_interval_cycles: 200_000,
            ..Default::default()
        }),
        ..Default::default()
    };
    let out = DeploymentManager::new(control.clone(), healthy).run(&records);
    for r in &out.reports {
        println!("  [{:?}] {}", r.stage, r.detail);
    }
    println!("  => final: {:?}\n", out.final_stage);
    assert_eq!(out.final_stage, DeployStage::Steady);

    println!("== playbook run 2: poisoned candidate (absurd P95 gate) ==");
    let mut dm = DeploymentManager::new(
        control,
        SimConfig {
            prefetcher: PrefetcherKind::Ceip { entries: 4096, window: 8, whole_window: true },
            ..Default::default()
        },
    );
    // Simulate an operator requiring a 2x P95 *improvement* before ramp —
    // the canary gate must trip and roll back automatically.
    dm.gates = Gates {
        p95_ratio_max: 0.5,
        ..Default::default()
    };
    let out = dm.run(&records);
    for r in &out.reports {
        println!("  [{:?}] {}", r.stage, r.detail);
    }
    println!("  => final: {:?}", out.final_stage);
    assert_eq!(out.final_stage, DeployStage::RolledBack);
    println!("\nplaybook behaves as §VI-A specifies: blast radius is bounded by");
    println!("shadow validation and the guarded-canary automatic rollback.");
}
