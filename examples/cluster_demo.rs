//! Cluster simulator walkthrough: run the fan-out frontend DAG from
//! `examples/cluster.json` — 3 static prefetcher configs plus one
//! SLO-control-loop scenario per autoscaler policy under stationary and
//! bursty traffic — and show that (a) faster prefetchers tighten P99 at
//! fixed offered load and (b) the control loops buy back SLO compliance
//! during bursts at different replica/metadata cost points.
//!
//! Run: `cargo run --release --example cluster_demo [requests]`

use slofetch::cluster::{self, ClusterSpec};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let spec_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/cluster.json");
    let mut spec = ClusterSpec::load(&spec_path)?;
    // Re-validated override: the spec's own `requests = 0` check already
    // ran at load, so the CLI arg must not sneak a zero past it.
    if let Some(n) = std::env::args().nth(1).and_then(|s| s.parse().ok()) {
        anyhow::ensure!(n > 0, "requests override must be > 0");
        spec.requests = n;
    }
    println!(
        "== cluster demo: '{}' — {} services, {} configs, {} policies, {} shapes, {} req/scenario ==",
        spec.name,
        spec.topology.services.len(),
        spec.prefetchers.len(),
        spec.effective_policies()?.len(),
        spec.traffic.len(),
        spec.requests
    );
    let t0 = std::time::Instant::now();
    let out = cluster::run_spec(&spec, 0)?;
    println!(
        "({} requests, {} events in {:.1}s — {:.1}M events/s)\n",
        out.total_requests,
        out.total_events,
        t0.elapsed().as_secs_f64(),
        out.total_events as f64 / t0.elapsed().as_secs_f64().max(1e-9) / 1e6,
    );
    println!("{}", cluster::report(&out).markdown());
    if let Some(t) = cluster::action_report(&out) {
        println!("{}", t.markdown());
    }
    println!("each policy row trades a handful of control actions for the");
    println!("burst scenario's burned windows — compare their replica·s and");
    println!("metadata columns to see what that insurance costs: the paper's");
    println!("operational claim (§XI) driven end-to-end through the DAG engine.");
    Ok(())
}
