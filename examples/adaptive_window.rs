//! Window-size adaptation (paper §IV-B / §XIII): compare fixed windows
//! {4, 8, 12} against the contextual bandit choosing the effective window
//! per decision, on a phase-churning workload.
//!
//! Run: `cargo run --release --example adaptive_window`

use slofetch::config::{ControllerCfg, PrefetcherKind, SimConfig};
use slofetch::sim::engine;
use slofetch::trace::gen::{apps, generate_records};

fn main() {
    let records = generate_records(&apps::app("abscheduler-java").unwrap(), 9, 400_000);
    let nl = engine::run(&SimConfig::default(), &records);

    println!(
        "{:<16} {:>8} {:>9} {:>10} {:>9}",
        "variant", "speedup", "accuracy", "issued/ki", "skipped"
    );
    let run = |label: &str, window: u8, adapt: bool| {
        let cfg = SimConfig {
            prefetcher: PrefetcherKind::Ceip {
                entries: 4096,
                window,
                whole_window: true,
            },
            controller: if adapt {
                Some(ControllerCfg {
                    adapt_window: true,
                    train_interval_cycles: 250_000,
                    ..Default::default()
                })
            } else {
                None
            },
            ..Default::default()
        };
        let r = engine::run(&cfg, &records);
        let ki = r.stats.instrs as f64 / 1000.0;
        println!(
            "{:<16} {:>8.4} {:>9.3} {:>10.2} {:>9}",
            label,
            r.ipc() / nl.ipc(),
            r.stats.accuracy(),
            r.stats.pf_issued as f64 / ki,
            r.stats.pf_skipped
        );
    };
    run("fixed w=4", 4, false);
    run("fixed w=8", 8, false);
    run("fixed w=12", 12, false);
    // The bandit needs the superset window (12) to choose within.
    run("bandit {4,8,12}", 12, true);

    println!("\npaper §IX: larger windows add coverage but cost accuracy/bandwidth;");
    println!("the bandit tracks phase behaviour instead of committing statically.");
}
